"""Device-scaling benchmark for the sharded island engine (DESIGN.md §8).

Runs the same island DE configuration with the island axis laid over 1, 2, 4
and 8 devices (``core.mesh.MeshConfig``) and records *round throughput* —
sync rounds per second of the compiled run, excluding compilation — plus the
speedup over the 1-device (unsharded-engine) baseline. On a machine without
accelerators the mesh is host-platform devices: the script sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` itself (before jax
loads) unless the flag is already present, which is also how the CI
distributed-smoke job runs it.

Writes ``BENCH_distributed.json`` (the repo's scaling artifact; CI uploads
the --smoke variant) and exits non-zero unless the widest mesh beats the
1-device baseline by ``--min-speedup`` on at least one function.

    PYTHONPATH=src python benchmarks/distributed.py            # full
    PYTHONPATH=src python benchmarks/distributed.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import time

MAX_DEVICES = 8
_FLAG = "xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --{_FLAG}={MAX_DEVICES}").strip()

import jax  # noqa: E402  (after XLA_FLAGS so host devices exist)

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer, MeshConfig  # noqa: E402
from repro.functions import get  # noqa: E402


def time_devices(fn: str, devices: int, *, islands: int, pop: int, dim: int,
                 sync_every: int, budget: int, repeats: int) -> dict:
    """Median wall time of a compiled run on a ``devices``-wide mesh."""
    f = get(fn, dim)
    cfg = IslandConfig(n_islands=islands, pop=pop, dim=dim,
                       sync_every=sync_every, migration="ring",
                       max_evals=budget)
    opt = IslandOptimizer(
        ALGORITHMS["de"], cfg,
        mesh_cfg=MeshConfig(devices=devices) if devices > 1 else None)
    key = jax.random.PRNGKey(0)
    res = opt.minimize(f, key)              # compile + warm the caches
    n_rounds = res.n_gens // sync_every
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        opt.minimize(f, key)
        walls.append(time.perf_counter() - t0)
    wall = sorted(walls)[len(walls) // 2]
    return {
        "devices": devices,
        "wall_s": round(wall, 4),
        "rounds_per_s": round(n_rounds / wall, 2),
        "n_rounds": n_rounds,
        "best": res.value,
    }


def bench(functions: list[str], device_counts: list[int], **sizes) -> list[dict]:
    rows = []
    for fn in functions:
        base = None
        for d in device_counts:
            r = time_devices(fn, d, **sizes)
            base = base or r["rounds_per_s"]
            r["fn"] = fn
            r["speedup"] = round(r["rounds_per_s"] / base, 3)
            rows.append(r)
            print(f"{fn:12s} devices={d}  {r['rounds_per_s']:9.2f} rounds/s  "
                  f"({r['speedup']:.2f}x vs 1 device)")
    return rows


def straggler_bench(fn: str, *, islands: int, pop: int, dim: int,
                    sync_every: int, rounds: int, slow_factor: int = 4,
                    base_ms: float = 25.0) -> dict:
    """Straggler study (ISSUE 8 satellite): one island is ``slow_factor``x
    slower than the rest; compare **island-round throughput** of the barrier
    engine vs the async staleness-bounded engine under the same fault.

    The fault is injected host-side through the engines' own hooks, not
    modeled analytically: both runs go through the host-stepped round loop
    (``round_callback``), where a per-round sleep stands in for the slow
    island's extra compute.

    The straggler's step time is calibrated from a faultless timed run:
    ``fast_step = max(base_ms, measured per-tick compute)`` and the slow
    island takes ``slow_factor * fast_step``.

    * Barrier: the ``lax.ppermute`` round is a global barrier, so EVERY round
      waits the straggler's full step on top of its own compute — the
      callback sleeps ``slow_factor*fast_step`` once per round, and all
      ``islands`` islands advance per round.
    * Async: the mailbox engine lets the fast islands tick at their own
      cadence — the callback sleeps one ``fast_step`` per *tick*, and the
      straggler island steps only every ``slow_factor`` ticks
      (``AsyncSchedule.from_cadences``), exactly as many generations per
      wall-second as its 4x-slow hardware would manage.

    Reported throughput is island-rounds/second: how many island round-steps
    the federation completes per wall-clock second. The acceptance bar
    (async >= 2x barrier under a 4x straggler) is asserted by ``main``.
    """
    import dataclasses

    from repro.core import AsyncSchedule

    f = get(fn, dim)
    budget = islands * pop * (rounds * sync_every + 1)
    cfg_b = IslandConfig(n_islands=islands, pop=pop, dim=dim,
                         sync_every=sync_every, migration="ring",
                         max_evals=budget)
    cfg_a = dataclasses.replace(cfg_b, sync_policy="async",
                                max_staleness=slow_factor)

    def run(cfg, schedule, sleep_s):
        hook = lambda r, ba, bv: time.sleep(sleep_s)  # noqa: E731
        opt = IslandOptimizer(ALGORITHMS["de"], cfg, schedule=schedule,
                              round_callback=hook)
        opt.minimize(f, jax.random.PRNGKey(0))        # compile/warm
        t0 = time.perf_counter()
        opt.minimize(f, jax.random.PRNGKey(0))
        wall = time.perf_counter() - t0
        return opt, wall

    # Calibrate the fast islands' step time: a faultless timed run gives the
    # engine's own per-tick compute, and the straggler's step is modeled as
    # ``slow_factor`` times that (floored at base_ms so a toy config still
    # injects a visible fault). The barrier round then waits the straggler's
    # FULL step on top of its own compute; the async tick only ever waits the
    # fast step.
    _, wall_0 = run(cfg_b, None, 0.0)
    # 1.5x the measured tick keeps the injected fault dominant over the
    # host-stepped loop's dispatch overhead (which both engines pay alike).
    fast_step = max(base_ms / 1e3, 1.5 * wall_0 / rounds)
    _, wall_b = run(cfg_b, None, slow_factor * fast_step)
    sync_tp = islands * rounds / wall_b

    cadences = [1] * (islands - 1) + [slow_factor]    # island -1 is 4x slow
    sched = AsyncSchedule.from_cadences(cadences, rounds)
    opt_a, wall_a = run(cfg_a, sched, fast_step)
    step_m, _ = opt_a.recorded_schedule.materialize(rounds, islands)
    async_tp = float(step_m.sum()) / wall_a

    row = {
        "fn": fn, "islands": islands, "slow_factor": slow_factor,
        "base_ms": base_ms, "rounds": rounds,
        "sync_wall_s": round(wall_b, 4),
        "async_wall_s": round(wall_a, 4),
        "sync_island_rounds_per_s": round(sync_tp, 2),
        "async_island_rounds_per_s": round(async_tp, 2),
        "async_over_sync": round(async_tp / sync_tp, 3),
    }
    print(f"straggler {fn:12s} sync {sync_tp:8.2f} island-rounds/s | "
          f"async {async_tp:8.2f} | {row['async_over_sync']:.2f}x")
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: fewer rounds/repeats, widest mesh only")
    ap.add_argument("--functions", nargs="+",
                    default=["rastrigin", "rosenbrock"])
    ap.add_argument("--islands", type=int, default=8)
    ap.add_argument("--pop", type=int, default=512)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=60,
                    help="sync rounds per timed run (sets the eval budget)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="fail unless the widest mesh strictly beats this "
                         "on at least one function")
    ap.add_argument("--straggler-rounds", type=int, default=40,
                    help="ticks/rounds in the straggler study")
    ap.add_argument("--min-straggler-ratio", type=float, default=2.0,
                    help="fail unless async island-round throughput beats "
                         "the barrier engine by this under a 4x straggler")
    ap.add_argument("--out", default="BENCH_distributed.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    counts = [d for d in (1, 2, 4, 8) if d <= min(n_dev, args.islands)]
    if args.smoke:
        args.rounds, args.repeats = 25, 2
        args.straggler_rounds = 16
        counts = [1, counts[-1]] if counts[-1] > 1 else counts

    budget = args.islands * args.pop * (args.rounds * args.sync_every + 1)
    rows = bench(args.functions, counts,
                 islands=args.islands, pop=args.pop, dim=args.dim,
                 sync_every=args.sync_every, budget=budget,
                 repeats=args.repeats)

    straggler = straggler_bench(
        args.functions[0], islands=args.islands, pop=min(args.pop, 64),
        dim=args.dim, sync_every=args.sync_every,
        rounds=args.straggler_rounds)

    widest = counts[-1]
    best_by_fn = {fn: max(r["speedup"] for r in rows
                          if r["fn"] == fn and r["devices"] == widest)
                  for fn in args.functions}
    best = max(best_by_fn.values())
    rec = {
        "algo": "de", "migration": "ring", "islands": args.islands,
        "pop": args.pop, "dim": args.dim, "sync_every": args.sync_every,
        "rounds": args.rounds, "device_counts": counts,
        "backend": jax.default_backend(), "visible_devices": n_dev,
        "smoke": args.smoke, "rows": rows,
        "speedup_at_widest_by_fn": best_by_fn,
        "best_speedup": best,
        "straggler": straggler,
    }
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(f"\nbest {widest}-device speedup over the unsharded engine: "
          f"{best:.2f}x -> {args.out}")
    if best <= args.min_speedup:
        raise SystemExit(
            f"no function scaled past {args.min_speedup}x at {widest} devices")
    if straggler["async_over_sync"] < args.min_straggler_ratio:
        raise SystemExit(
            f"async throughput under a 4x straggler was only "
            f"{straggler['async_over_sync']}x the barrier engine's "
            f"(need >= {args.min_straggler_ratio}x)")


if __name__ == "__main__":
    main()
