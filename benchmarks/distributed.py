"""Device-scaling benchmark for the sharded island engine (DESIGN.md §8).

Runs the same island DE configuration with the island axis laid over 1, 2, 4
and 8 devices (``core.mesh.MeshConfig``) and records *round throughput* —
sync rounds per second of the compiled run, excluding compilation — plus the
speedup over the 1-device (unsharded-engine) baseline. On a machine without
accelerators the mesh is host-platform devices: the script sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` itself (before jax
loads) unless the flag is already present, which is also how the CI
distributed-smoke job runs it.

Writes ``BENCH_distributed.json`` (the repo's scaling artifact; CI uploads
the --smoke variant) and exits non-zero unless the widest mesh beats the
1-device baseline by ``--min-speedup`` on at least one function.

    PYTHONPATH=src python benchmarks/distributed.py            # full
    PYTHONPATH=src python benchmarks/distributed.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import time

MAX_DEVICES = 8
_FLAG = "xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --{_FLAG}={MAX_DEVICES}").strip()

import jax  # noqa: E402  (after XLA_FLAGS so host devices exist)

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer, MeshConfig  # noqa: E402
from repro.functions import get  # noqa: E402


def time_devices(fn: str, devices: int, *, islands: int, pop: int, dim: int,
                 sync_every: int, budget: int, repeats: int) -> dict:
    """Median wall time of a compiled run on a ``devices``-wide mesh."""
    f = get(fn, dim)
    cfg = IslandConfig(n_islands=islands, pop=pop, dim=dim,
                       sync_every=sync_every, migration="ring",
                       max_evals=budget)
    opt = IslandOptimizer(
        ALGORITHMS["de"], cfg,
        mesh_cfg=MeshConfig(devices=devices) if devices > 1 else None)
    key = jax.random.PRNGKey(0)
    res = opt.minimize(f, key)              # compile + warm the caches
    n_rounds = res.n_gens // sync_every
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        opt.minimize(f, key)
        walls.append(time.perf_counter() - t0)
    wall = sorted(walls)[len(walls) // 2]
    return {
        "devices": devices,
        "wall_s": round(wall, 4),
        "rounds_per_s": round(n_rounds / wall, 2),
        "n_rounds": n_rounds,
        "best": res.value,
    }


def bench(functions: list[str], device_counts: list[int], **sizes) -> list[dict]:
    rows = []
    for fn in functions:
        base = None
        for d in device_counts:
            r = time_devices(fn, d, **sizes)
            base = base or r["rounds_per_s"]
            r["fn"] = fn
            r["speedup"] = round(r["rounds_per_s"] / base, 3)
            rows.append(r)
            print(f"{fn:12s} devices={d}  {r['rounds_per_s']:9.2f} rounds/s  "
                  f"({r['speedup']:.2f}x vs 1 device)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: fewer rounds/repeats, widest mesh only")
    ap.add_argument("--functions", nargs="+",
                    default=["rastrigin", "rosenbrock"])
    ap.add_argument("--islands", type=int, default=8)
    ap.add_argument("--pop", type=int, default=512)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=60,
                    help="sync rounds per timed run (sets the eval budget)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="fail unless the widest mesh strictly beats this "
                         "on at least one function")
    ap.add_argument("--out", default="BENCH_distributed.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    counts = [d for d in (1, 2, 4, 8) if d <= min(n_dev, args.islands)]
    if args.smoke:
        args.rounds, args.repeats = 25, 2
        counts = [1, counts[-1]] if counts[-1] > 1 else counts

    budget = args.islands * args.pop * (args.rounds * args.sync_every + 1)
    rows = bench(args.functions, counts,
                 islands=args.islands, pop=args.pop, dim=args.dim,
                 sync_every=args.sync_every, budget=budget,
                 repeats=args.repeats)

    widest = counts[-1]
    best_by_fn = {fn: max(r["speedup"] for r in rows
                          if r["fn"] == fn and r["devices"] == widest)
                  for fn in args.functions}
    best = max(best_by_fn.values())
    rec = {
        "algo": "de", "migration": "ring", "islands": args.islands,
        "pop": args.pop, "dim": args.dim, "sync_every": args.sync_every,
        "rounds": args.rounds, "device_counts": counts,
        "backend": jax.default_backend(), "visible_devices": n_dev,
        "smoke": args.smoke, "rows": rows,
        "speedup_at_widest_by_fn": best_by_fn,
        "best_speedup": best,
    }
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(f"\nbest {widest}-device speedup over the unsharded engine: "
          f"{best:.2f}x -> {args.out}")
    if best <= args.min_speedup:
        raise SystemExit(
            f"no function scaled past {args.min_speedup}x at {widest} devices")


if __name__ == "__main__":
    main()
