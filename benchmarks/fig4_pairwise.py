"""Fig. 4 reproduction: pairwise comparison of the paper's 15 method
configurations over the §V.B testbed with an evaluation budget of 1000*D,
sign / signed-rank / t tests at 95%.

The paper runs 1000-D with 1M evaluations x 10 repeats (hours per cell on a
laptop-class JVM); this harness exposes the identical protocol with
--dim/--repeats/--budget-scale knobs so the CPU container runs a reduced but
statistically identical pipeline, and a pod runs the full one.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.stats import sign_test, signed_rank_test, t_test
from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.core.coupling import with_fcg_postprocessing
from repro.functions import get
from repro.optim import DescentConfig, asd, avd, fcg

# the paper's Fig.4 configurations (§V.B items 1..15)
METHOD_SETUP = {
    "ga": dict(algo="ga", pop=100, params={"pc": 0.7, "pm": 0.1}),
    "sa": dict(algo="sa", pop=100, params={"schedule": "linear", "T0": 1000.0}),
    "ea": dict(algo="ea", pop=100, params={}),
    "de": dict(algo="de", pop=100, params={"px": 0.8, "w": 0.9}),
    "ps": dict(algo="pso", pop=10, params={"w": 0.6, "fp": 1.0, "fg": 1.0}),
    "fa": dict(algo="fa", pop=50, params={"beta0": 1.0, "gamma": 200.0,
                                          "delta": 0.97}),
    "mc": dict(algo="mc", pop=100, params={}),
}
GRAD_METHODS = {"asd": asd, "avd": avd, "fcg": fcg}
COMBOS = ["gafcg", "eafcg", "safcg", "defcg", "psfcg"]
ALL_METHODS = list(METHOD_SETUP) + list(GRAD_METHODS) + COMBOS

FUNCTIONS = ["ackley", "rastrigin", "rosenbrock", "dropwave", "schwefel",
             "griewank", "trid", "michalewicz", "sphere", "weierstrass",
             "lnd1", "lnd2", "lnd3", "lnd4", "lnd5", "lnd6", "lnd7"]


def run_method(name: str, fname: str, dim: int, budget: int, seed: int) -> float:
    f = get(fname, dim)
    key = jax.random.PRNGKey(seed * 77 + hash(name + fname) % 1000)
    if name in METHOD_SETUP:
        m = METHOD_SETUP[name]
        cfg = IslandConfig(n_islands=1, pop=m["pop"], dim=dim,
                           migration="none", max_evals=budget)
        params = dict(m["params"])
        if m["algo"] == "sa":
            params["n_gens_hint"] = max(budget // m["pop"], 1)
        return IslandOptimizer(ALGORITHMS[m["algo"]], cfg,
                               params=params).minimize(f, key).value
    if name in GRAD_METHODS:
        return GRAD_METHODS[name](f, key, dim,
                                  DescentConfig(max_evals=budget)).value
    # X/FCG combos: 50-50 budget split
    base = name[:2].replace("ps", "pso")
    base = {"ga": "ga", "ea": "ea", "sa": "sa", "de": "de", "pso": "pso"}[base]
    m = METHOD_SETUP[{"pso": "ps"}.get(base, base)]
    meta = IslandOptimizer(
        ALGORITHMS[base],
        IslandConfig(n_islands=1, pop=m["pop"], dim=dim, migration="none"),
        params=m["params"])
    return with_fcg_postprocessing(meta, f, key, dim, total_evals=budget).value


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--budget-scale", type=int, default=1000,
                    help="evals = scale * dim (paper: 1000)")
    ap.add_argument("--methods", default=None, help="comma list (default all 15)")
    ap.add_argument("--functions", default=None)
    ap.add_argument("--out", default="experiments/fig4.json")
    args = ap.parse_args()

    methods = args.methods.split(",") if args.methods else ALL_METHODS
    fnames = args.functions.split(",") if args.functions else FUNCTIONS
    budget = args.budget_scale * args.dim

    results: dict[str, dict[str, list[float]]] = {m: {} for m in methods}
    for m in methods:
        for fn in fnames:
            t0 = time.time()
            vals = [run_method(m, fn, args.dim, budget, r)
                    for r in range(args.repeats)]
            results[m][fn] = vals
            print(f"fig4 {m:7s} {fn:12s} mean={np.mean(vals):12.4g} "
                  f"({time.time()-t0:.1f}s)", flush=True)

    # pairwise matrix with the paper's notation: winner[s,sr,t]
    matrix = {}
    for i, a in enumerate(methods):
        for b in methods[i + 1:]:
            va = np.array([np.mean(results[a][fn]) for fn in fnames])
            vb = np.array([np.mean(results[b][fn]) for fn in fnames])
            wins_a = int(np.sum(va < vb))
            winner = a if wins_a * 2 >= len(fnames) else b
            tags = []
            for tag, test in (("s", sign_test), ("sr", signed_rank_test),
                              ("t", t_test)):
                w, sig = test(va, vb)
                if sig and ((w == "a") == (winner == a)):
                    tags.append(tag)
            matrix[f"{a}|{b}"] = f"{winner}[{','.join(tags)}]"
    with open(args.out, "w") as fh:
        json.dump({"dim": args.dim, "budget": budget,
                   "repeats": args.repeats, "results": results,
                   "matrix": matrix}, fh, indent=1)
    print("\n== Fig.4 pairwise matrix ==")
    for k, v in matrix.items():
        print(f"  {k:16s} -> {v}")


if __name__ == "__main__":
    main()
