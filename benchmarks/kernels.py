"""Fused-kernel vs unfused-XLA generation throughput, across policies and
shape classes. Writes ``BENCH_kernels.json`` (the fused-kernel perf artifact;
CI uploads the --smoke variant).

    PYTHONPATH=src python benchmarks/kernels.py            # full
    PYTHONPATH=src python benchmarks/kernels.py --smoke    # CI-sized

For each (policy, function, shape) cell both sides run the SAME policy
construction — ``make(...)`` vs ``make(..., fused=True)`` — stepped under
``jax.jit`` from one shared initial state with the same key chain, so the
speedup isolates the fused Pallas generation kernel (autotuned tiles via
``kernels.autotune``) against the per-op XLA pipeline plus the executor's
retry-eval. Per-cell time is the best of ``--reps`` timed windows (medians of
noisy CPU runs understate steady state); generations/sec follow directly. A
parity probe asserts the first fused generation matches the unfused one, so a
speedup can never come from computing something else.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import ExecutorConfig, ga, pso
from repro.core.executor import make_batch_evaluator
from repro.functions import get

POLICIES = {"pso": pso.make, "ga": ga.make}


def _time_gens(step, state, key, n_gens: int, reps: int) -> float:
    """Best-of-``reps`` seconds per generation for a step function run as a
    jitted ``lax.scan`` block of ``n_gens`` generations — the same shape the
    island engine executes (device-resident rounds), so per-generation host
    dispatch does not dilute the kernel-vs-XLA ratio."""

    @jax.jit
    def block(s, k):
        keys = jax.random.split(k, n_gens)
        return jax.lax.scan(lambda c, kk: (step(c, kk), None), s, keys)[0]

    jax.block_until_ready(block(dict(state), key))   # compile + warm caches
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(block(dict(state), jax.random.fold_in(key, r)))
        best = min(best, (time.perf_counter() - t0) / n_gens)
    return best


def _parity(plain, fused, state, key) -> float:
    """Max relative divergence of one fused vs unfused generation (same key)."""
    sp = jax.jit(plain.gen)(dict(state), key)
    sf = jax.jit(fused.step_override)(dict(state), key)

    def rel(a, b):
        a, b = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
        return float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1.0)))

    return max(rel(sf[k], sp[k]) for k in sp)


def bench_cell(policy: str, fn: str, pop: int, dim: int, n_gens: int,
               reps: int) -> dict:
    f = get(fn)
    ev = make_batch_evaluator(f, ExecutorConfig())
    # GA offspring waves sized to the population so both sides do comparable
    # per-generation work (the default pop//4 wave times mostly XLA overhead).
    kw = {"n_offspring": pop} if policy == "ga" else {}
    maker = POLICIES[policy]
    plain = maker(f=f, evaluator=ev, pop=pop, dim=dim, **kw)
    fused = maker(f=f, evaluator=ev, pop=pop, dim=dim, fused=True, **kw)
    key = jax.random.PRNGKey(0)
    state = plain.init(key)
    div = _parity(plain, fused, state, jax.random.fold_in(key, 1))
    t_un = _time_gens(plain.gen, state, key, n_gens, reps)
    t_fu = _time_gens(fused.step_override, state, key, n_gens, reps)
    return {
        "policy": policy, "fn": fn, "pop": pop, "dim": dim,
        "gens_per_s_unfused": round(1.0 / t_un, 2),
        "gens_per_s_fused": round(1.0 / t_fu, 2),
        "t_unfused_ms": round(t_un * 1e3, 3),
        "t_fused_ms": round(t_fu * 1e3, 3),
        "speedup": round(t_un / t_fu, 3),
        "parity_max_rel": div,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer generations/reps, one shape)")
    ap.add_argument("--functions", nargs="*",
                    default=["sphere", "rastrigin", "griewank", "ackley",
                             "schwefel"])
    ap.add_argument("--shapes", nargs="*", default=["128x1000"],
                    help="POPxDIM shape classes, e.g. 128x1000 256x512")
    ap.add_argument("--gens", type=int, default=30)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()

    if args.smoke:
        args.gens, args.reps = 10, 2

    from repro.kernels import autotune
    cells = []
    for shape in args.shapes:
        pop, dim = (int(x) for x in shape.split("x"))
        for fn in args.functions:
            for policy in POLICIES:
                cell = bench_cell(policy, fn, pop, dim, args.gens, args.reps)
                cells.append(cell)
                print(f"{policy:4s} {fn:12s} {pop}x{dim}: "
                      f"{cell['speedup']:.2f}x "
                      f"({cell['t_unfused_ms']:.1f} -> "
                      f"{cell['t_fused_ms']:.1f} ms/gen)")
    ok = {p: sorted(c["fn"] for c in cells
                    if c["policy"] == p and c["speedup"] >= 1.3)
          for p in POLICIES}
    rec = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "gens": args.gens, "reps": args.reps, "smoke": args.smoke,
        "autotune": autotune.cache_stats(),
        "cells": cells,
        "fns_ge_1p3x": ok,
    }
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(json.dumps({k: rec[k] for k in rec if k != "cells"}, indent=2))
    bad = [c for c in cells if c["parity_max_rel"] > 1e-3]
    if bad:
        raise SystemExit(f"fused/unfused parity broke: {bad}")


if __name__ == "__main__":
    main()
