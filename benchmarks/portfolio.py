"""Algorithm-portfolio islands vs single meta-heuristics at equal eval budget
(DESIGN.md §10 — the paper's Fig.4 cooperation scenario).

For each registry testbed function, run a mixed DE+PSO+SA portfolio (one
policy per island, cycled; ring migration + shared incumbent) against each
single algorithm run homogeneous over the SAME island topology and the SAME
function-evaluation budget, and record the median best objective over seeds.
Every (function, variant) cell is ONE jitted jobs-axis dispatch
(``minimize_many`` over the seed axis).

Writes ``BENCH_portfolio.json`` (the repo's portfolio-quality artifact; CI
uploads the --smoke variant) and exits non-zero unless the portfolio

* beats the WORST single algorithm's median on every function, and
* beats the BEST single algorithm's median on at least ``--min-best-wins``
  functions

— the "no single method dominates, the portfolio hedges" claim, quantified.

    PYTHONPATH=src python benchmarks/portfolio.py            # full run
    PYTHONPATH=src python benchmarks/portfolio.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.functions import get

FUNCTIONS = ("sphere", "rosenbrock", "griewank", "levy", "ackley",
             "rastrigin", "schwefel", "dropwave")
SINGLES = ("de", "pso", "sa")


def _sa_params(budget: int, pop: int, n_islands: int, sync_every: int,
               t0: float, step_frac: float) -> dict:
    """SA tuned as a *local refiner* (low T0, small steps — it polishes the
    good migrants the ring delivers, the cooperation mechanism that lets the
    mixed portfolio beat its best constituent), annealing over the run's
    actual generation horizon so single-SA runs and the portfolio's SA
    islands cool at one rate. The same params go to the single-SA baseline —
    the comparison stays algorithm-fair."""
    rounds = max(1, (budget - pop * n_islands) // (pop * n_islands * sync_every))
    return {"T0": t0, "step_frac": step_frac, "n_gens_hint": rounds * sync_every}


def run_variant(fn: str, dim: int, pop: int, n_islands: int, budget: int,
                sync_every: int, seeds: int, portfolio: tuple[str, ...] | None,
                algo: str | None, sa_t0: float, sa_step_frac: float) -> dict:
    f = get(fn, dim)
    cfg = IslandConfig(
        n_islands=n_islands, pop=pop, dim=dim, sync_every=sync_every,
        migration="ring", share_incumbent=True, max_evals=budget,
        portfolio=portfolio or ())
    sa = _sa_params(budget, pop, n_islands, sync_every, sa_t0, sa_step_frac)
    if portfolio:
        params = {"sa": sa} if "sa" in portfolio else {}
        opt = IslandOptimizer(None, cfg, params=params)
    else:
        opt = IslandOptimizer(ALGORITHMS[algo], cfg,
                              params=sa if algo == "sa" else {})
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(seeds)])
    t0 = time.perf_counter()
    results = opt.minimize_many(f, keys)   # one dispatch for all seeds
    dt = time.perf_counter() - t0
    values = [r.value for r in results]
    return {
        "median": statistics.median(values),
        "best": min(values),
        "worst": max(values),
        "n_evals": results[0].n_evals,
        "wall_s": round(dt, 3),
    }


def bench(dim: int, pop: int, n_islands: int, budget: int, sync_every: int,
          seeds: int, portfolio: tuple[str, ...], sa_t0: float,
          sa_step_frac: float) -> list[dict]:
    rows = []
    for fn in FUNCTIONS:
        singles = {a: run_variant(fn, dim, pop, n_islands, budget, sync_every,
                                  seeds, None, a, sa_t0, sa_step_frac)
                   for a in SINGLES}
        port = run_variant(fn, dim, pop, n_islands, budget, sync_every,
                           seeds, portfolio, None, sa_t0, sa_step_frac)
        best_single = min(SINGLES, key=lambda a: singles[a]["median"])
        worst_single = max(SINGLES, key=lambda a: singles[a]["median"])
        rows.append({
            "fn": fn, "singles": singles, "portfolio": port,
            "best_single": best_single, "worst_single": worst_single,
            "beats_worst": port["median"] < singles[worst_single]["median"],
            "beats_best": port["median"] < singles[best_single]["median"],
        })
        marks = " ".join(f"{a}={singles[a]['median']:.4g}" for a in SINGLES)
        print(f"{fn:12s} portfolio {port['median']:12.5g}  [{marks}]  "
              f"{'BEATS-BEST' if rows[-1]['beats_best'] else ''}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: fewer seeds, smaller budget")
    ap.add_argument("--dim", type=int, default=12)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--islands", type=int, default=6)
    ap.add_argument("--sync-every", type=int, default=5)
    ap.add_argument("--budget", type=int, default=24000)
    ap.add_argument("--seeds", type=int, default=9)
    ap.add_argument("--portfolio", default="de,pso,sa",
                    help="comma list, cycled over the islands")
    ap.add_argument("--sa-t0", type=float, default=5.0,
                    help="SA initial temperature (low: SA as local refiner)")
    ap.add_argument("--sa-step-frac", type=float, default=0.02,
                    help="SA proposal sigma as a fraction of the box width")
    ap.add_argument("--min-best-wins", type=int, default=2,
                    help="fail unless the portfolio beats the best single's "
                         "median on this many functions")
    ap.add_argument("--out", default="BENCH_portfolio.json")
    args = ap.parse_args()

    if args.smoke:
        args.seeds, args.budget = 5, 12000

    portfolio = tuple(args.portfolio.split(","))
    rows = bench(args.dim, args.pop, args.islands, args.budget,
                 args.sync_every, args.seeds, portfolio, args.sa_t0,
                 args.sa_step_frac)
    worst_ok = sum(r["beats_worst"] for r in rows)
    best_wins = sum(r["beats_best"] for r in rows)
    rec = {
        "portfolio": list(portfolio), "singles": list(SINGLES),
        "dim": args.dim, "pop": args.pop, "n_islands": args.islands,
        "sync_every": args.sync_every, "budget": args.budget,
        "sa_t0": args.sa_t0, "sa_step_frac": args.sa_step_frac,
        "seeds": args.seeds, "backend": jax.default_backend(),
        "smoke": args.smoke, "rows": rows,
        "beats_worst_on": worst_ok, "beats_best_on": best_wins,
        "n_functions": len(FUNCTIONS),
    }
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(f"\nportfolio {'+'.join(portfolio)} beats the worst single on "
          f"{worst_ok}/{len(FUNCTIONS)} and the best single on "
          f"{best_wins}/{len(FUNCTIONS)} functions -> {args.out}")
    if worst_ok < len(FUNCTIONS):
        raise SystemExit(
            f"portfolio lost to the worst single algorithm on "
            f"{len(FUNCTIONS) - worst_ok} functions")
    if best_wins < args.min_best_wins:
        raise SystemExit(
            f"portfolio beat the best single on only {best_wins} functions "
            f"(< {args.min_best_wins})")


if __name__ == "__main__":
    main()
