"""Benchmark harness: one entry per paper artifact. Prints
``name,us_per_call,derived`` CSV rows.

  table1_de_gen      §V.A DDE generation step (shifted Rosenbrock-1000, pop 800)
  fig4_lite          §V.B pairwise subset (5 methods x 5 functions, reduced dim)
  executor_eval      distributed-evaluator throughput per backend (xla/pallas)
  fused_de_island    device-resident DDE: XLA step vs fused de_step kernel
  de_kernel_parity   fused de_step kernel vs XLA reference (correctness +
                     relative call time; Pallas runs interpreted on CPU)
  roofline_summary   per-cell dominant terms from the saved dry-run artifacts

Full-budget reproductions: benchmarks/table1_de_scaling.py and
benchmarks/fig4_pairwise.py (see EXPERIMENTS.md).
"""
from __future__ import annotations

import glob
import json
import sys
import time

import jax
import jax.numpy as jnp


def _t(fn, n=3):
    fn()  # compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


def table1_de_gen() -> None:
    from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
    from repro.functions import make_shifted_rosenbrock
    f = make_shifted_rosenbrock(1000)
    cfg = IslandConfig(n_islands=1, pop=800, dim=1000, migration="none",
                       sync_every=10, max_evals=800 * 50)
    opt = IslandOptimizer(ALGORITHMS["de"], cfg,
                          params={"w": 0.5, "px": 0.2, "barrier_mode": "chunked"})
    t0 = time.time()
    res = opt.minimize(f, jax.random.PRNGKey(0))
    wall = time.time() - t0
    per_gen = wall / max(res.n_gens, 1) * 1e6
    print(f"table1_de_gen,{per_gen:.1f},best={res.value:.1f}")


def fig4_lite() -> None:
    from benchmarks.fig4_pairwise import run_method
    methods = ["sa", "ga", "de", "mc", "fcg"]
    fns = ["sphere", "rastrigin", "rosenbrock", "ackley", "lnd1"]
    t0 = time.time()
    wins = {m: 0 for m in methods}
    vals = {m: [] for m in methods}
    for fn in fns:
        for m in methods:
            vals[m].append(run_method(m, fn, 16, 8000, 0))
    for i, fn in enumerate(fns):
        best = min(methods, key=lambda m: vals[m][i])
        wins[best] += 1
    per = (time.time() - t0) / (len(methods) * len(fns)) * 1e6
    order = sorted(wins, key=lambda m: -wins[m])
    print(f"fig4_lite,{per:.0f},winner_order={'>'.join(order)}")


def executor_eval() -> None:
    """Distributed-evaluator throughput per EvalBackend (xla vs pallas)."""
    from repro.core.executor import ExecutorConfig, make_batch_evaluator
    from repro.functions import get
    pop = jax.random.uniform(jax.random.PRNGKey(0), (4096, 256),
                             minval=-5, maxval=5)
    for backend in ("xla", "pallas"):
        ev = jax.jit(make_batch_evaluator(get("rastrigin"),
                                          ExecutorConfig(backend=backend)))
        us = _t(lambda: ev(pop).block_until_ready())
        print(f"executor_eval_{backend},{us:.1f},evals_per_s={4096/us*1e6:.0f}")


def fused_de_island() -> None:
    """DDE under the device-resident engine, XLA step vs fused de_step kernel."""
    from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
    from repro.functions import get
    f = get("rastrigin")
    cfg = IslandConfig(n_islands=1, pop=256, dim=128, migration="none",
                       sync_every=10, max_evals=256 * 40)
    for fused in (False, True):
        opt = IslandOptimizer(ALGORITHMS["de"], cfg, params={"fused": fused})
        opt.minimize(f, jax.random.PRNGKey(0))        # warm the jit cache
        t0 = time.time()
        res = opt.minimize(f, jax.random.PRNGKey(0))
        per_gen = (time.time() - t0) / max(res.n_gens, 1) * 1e6
        tag = "fused" if fused else "xla"
        print(f"fused_de_island_{tag},{per_gen:.0f},best={res.value:.1f}")


def de_kernel_parity() -> None:
    from repro.kernels import ops, ref
    P, D = 256, 1000
    key = jax.random.PRNGKey(1)
    pop = jax.random.uniform(key, (P, D), minval=-100, maxval=100)
    fit = ref.bench_eval_ref(pop, "rastrigin")
    i = jnp.arange(P)
    idx = jnp.stack([(i + 3) % P, (i + 7) % P, (i + 11) % P])
    u = jax.random.uniform(jax.random.fold_in(key, 2), (P, D))
    jr = jax.random.randint(jax.random.fold_in(key, 3), (P,), 0, D)
    a1, a2 = ops.de_step(pop, fit, idx, u, jr, fn="rastrigin")
    b1, b2 = ref.de_step_ref(pop, fit, idx, u, jr, fn="rastrigin")
    err = float(jnp.max(jnp.abs(a2 - b2) / (jnp.abs(b2) + 1)))
    us = _t(lambda: ops.de_step(pop, fit, idx, u, jr, fn="rastrigin")[1]
            .block_until_ready(), n=1)
    print(f"de_kernel_parity,{us:.0f},maxrelerr={err:.2e}(interpret-mode)")


def roofline_summary() -> None:
    cells = sorted(glob.glob("experiments/dryrun/*.json"))
    n_ok = n_fit = 0
    worst = (0.0, "")
    for c in cells:
        r = json.load(open(c))
        if r.get("status") != "ok":
            continue
        n_ok += 1
        if r["memory"].get("fits_16GB_analytic"):
            n_fit += 1
        tx = r["per_device"]["t_collective"]
        if tx > worst[0]:
            worst = (tx, f"{r['arch']}/{r['shape']}")
    print(f"roofline_summary,{n_ok},fit16GB={n_fit} worst_tx={worst[1]}")


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (table1_de_gen, fig4_lite, executor_eval, fused_de_island,
               de_kernel_parity, roofline_summary):
        try:
            fn()
        except Exception as e:  # keep the harness running
            print(f"{fn.__name__},nan,ERROR:{type(e).__name__}:{e}",
                  file=sys.stdout)


if __name__ == "__main__":
    main()
