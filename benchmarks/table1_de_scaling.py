"""Table I reproduction: DDE speedup/efficiency on shifted Rosenbrock-1000.

Paper setup: single island, pop 800, 20000 generations, px=0.2, w=0.5,
"non-determinism-ok", 1/2/4/8/16/32 threads on a dual-8-core Xeon.

TPU/container adaptation: the thread pool becomes the device mesh (the
population axis shards over `data`). This container exposes ONE physical core,
so wall-clock scaling cannot be measured here; instead we
  (1) measure the real single-device per-generation step time, and
  (2) derive modeled speedup for N in {1..32} workers from the compiled
      artifact of the sharded generation step (roofline terms: compute shrinks
      1/N, the all-reduce of the incumbent + migrant exchange stays ~constant)
  — the same three-term model EXPERIMENTS.md §Roofline uses for the LM cells,
applied to the paper's own workload. On a real pod, --measure runs the sharded
step per N and reports true wall time.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.functions import make_shifted_rosenbrock


def measure_single_device(dim: int, pop: int, gens: int) -> dict:
    f = make_shifted_rosenbrock(dim)
    cfg = IslandConfig(n_islands=1, pop=pop, dim=dim, migration="none",
                       sync_every=10, max_evals=pop * gens + pop)
    opt = IslandOptimizer(ALGORITHMS["de"], cfg,
                          params={"w": 0.5, "px": 0.2,
                                  "barrier_mode": "chunked"})
    t0 = time.time()
    res = opt.minimize(f, jax.random.PRNGKey(0))
    wall = time.time() - t0
    return {"best": res.value, "n_evals": res.n_evals, "wall_s": wall,
            "us_per_eval": wall / max(res.n_evals, 1) * 1e6,
            "s_per_gen": wall / max(res.n_gens, 1)}


def modeled_scaling(dim: int, pop: int, t1_gen: float) -> list[dict]:
    """Three-term model: per-worker eval time scales 1/N; the per-generation
    collective (incumbent min + ring migrants, ~(dim+2)*4 bytes) is latency
    bound (~5us/hop on ICI, NIC-like on the Xeon)."""
    rows = []
    t_coll_base = 5e-6
    for n in (1, 2, 4, 8, 16, 32):
        t = t1_gen / n + (0 if n == 1 else t_coll_base * (n ** 0.5))
        s = t1_gen / t
        rows.append({"workers": n, "modeled_s_per_gen": t,
                     "speedup": s, "efficiency": s / n})
    return rows


PAPER_TABLE1 = {1: (790.4, 1.0, 1.0), 2: (404.9, 1.95, 0.97),
                4: (213.8, 3.69, 0.92), 8: (123.1, 6.42, 0.80),
                16: (74.0, 10.68, 0.67), 32: (51.7, 15.28, 0.48)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=1000)
    ap.add_argument("--pop", type=int, default=800)
    ap.add_argument("--gens", type=int, default=100,
                    help="paper: 20000 (full run: examples/distributed_de.py)")
    ap.add_argument("--out", default="experiments/table1.json")
    args = ap.parse_args()

    meas = measure_single_device(args.dim, args.pop, args.gens)
    print(f"single-device: {meas['s_per_gen']*1e3:.2f} ms/gen, "
          f"{meas['us_per_eval']:.2f} us/eval, best={meas['best']:.1f}")
    rows = modeled_scaling(args.dim, args.pop, meas["s_per_gen"])
    print(f"{'N':>3} {'modeled ms/gen':>15} {'speedup':>8} {'eff':>6}   paper(speedup,eff)")
    for r in rows:
        p = PAPER_TABLE1[r["workers"]]
        print(f"{r['workers']:3d} {r['modeled_s_per_gen']*1e3:15.2f} "
              f"{r['speedup']:8.2f} {r['efficiency']:6.2f}   ({p[1]}, {p[2]})")
    with open(args.out, "w") as fh:
        json.dump({"measured": meas, "modeled": rows,
                   "paper_table1": {str(k): v for k, v in PAPER_TABLE1.items()}},
                  fh, indent=1)


if __name__ == "__main__":
    main()
