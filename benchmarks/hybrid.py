"""Hybrid memetic layer: quality per eval budget vs plain DE (DESIGN.md §6).

For each registry testbed function, run plain island DE and hybrid DE+ASD
(in-scan polish: ``IslandConfig.polish``) at the SAME function-evaluation
budget — polish evals are charged to ``max_evals`` by the engine, so the
comparison is budget-fair — and record the median best objective over seeds.
Writes ``BENCH_hybrid.json`` (the repo's hybrid-quality artifact; CI uploads
the --smoke variant) and exits non-zero unless hybrid reaches a strictly
better median than plain on at least ``--min-wins`` functions.

    PYTHONPATH=src python benchmarks/hybrid.py            # full (2 budgets)
    PYTHONPATH=src python benchmarks/hybrid.py --smoke    # CI-sized

Each (function, variant, budget) cell is ONE jitted jobs-axis dispatch
(``minimize_many`` over the seed axis), so the whole sweep costs
#functions x #variants x #budgets compiles.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.functions import get

FUNCTIONS = ("sphere", "rosenbrock", "griewank", "levy", "ackley", "rastrigin")


def run_variant(fn: str, dim: int, pop: int, n_islands: int, budget: int,
                seeds: int, polish: dict | None) -> dict:
    f = get(fn, dim)
    cfg = IslandConfig(n_islands=n_islands, pop=pop, dim=dim, sync_every=10,
                       migration="ring", max_evals=budget, **(polish or {}))
    opt = IslandOptimizer(ALGORITHMS["de"], cfg)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(seeds)])
    t0 = time.perf_counter()
    results = opt.minimize_many(f, keys)   # one dispatch for all seeds
    dt = time.perf_counter() - t0
    values = [r.value for r in results]
    return {
        "median": statistics.median(values),
        "best": min(values),
        "worst": max(values),
        "n_evals": results[0].n_evals,     # budget actually consumed per job
        "wall_s": round(dt, 3),
    }


def bench(dim: int, pop: int, n_islands: int, budgets: list[int], seeds: int,
          polish: dict) -> list[dict]:
    rows = []
    for fn in FUNCTIONS:
        for budget in budgets:
            plain = run_variant(fn, dim, pop, n_islands, budget, seeds, None)
            hybrid = run_variant(fn, dim, pop, n_islands, budget, seeds, polish)
            rows.append({
                "fn": fn, "budget": budget,
                "plain": plain, "hybrid": hybrid,
                "hybrid_wins": hybrid["median"] < plain["median"],
            })
            print(f"{fn:12s} B={budget:6d}  plain {plain['median']:12.5g}  "
                  f"hybrid {hybrid['median']:12.5g}  "
                  f"{'HYBRID' if rows[-1]['hybrid_wins'] else 'plain'}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one budget, fewer seeds")
    ap.add_argument("--dim", type=int, default=12)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--islands", type=int, default=2)
    ap.add_argument("--seeds", type=int, default=9)
    ap.add_argument("--budgets", type=int, nargs="+", default=[6000, 12000])
    ap.add_argument("--polish", default="asd")
    ap.add_argument("--polish-every", type=int, default=3)
    ap.add_argument("--polish-topk", type=int, default=2)
    ap.add_argument("--polish-steps", type=int, default=2)
    ap.add_argument("--min-wins", type=int, default=3,
                    help="fail unless hybrid wins this many functions")
    ap.add_argument("--out", default="BENCH_hybrid.json")
    args = ap.parse_args()

    if args.smoke:
        args.seeds, args.budgets = 5, [12000]

    polish = dict(polish=args.polish, polish_every=args.polish_every,
                  polish_topk=args.polish_topk, polish_steps=args.polish_steps)
    rows = bench(args.dim, args.pop, args.islands, args.budgets, args.seeds,
                 polish)
    # Wins are judged at the headline (largest) budget; smaller budgets are
    # recorded as the quality-per-eval-budget curve. Polish pays off in the
    # mid-convergence regime — at tiny budgets it is premature (the global
    # phase has not found good basins yet) and at huge budgets both variants
    # converge to the optimum and tie.
    headline = max(args.budgets)
    by_fn = {fn: next(r["hybrid_wins"] for r in rows
                      if r["fn"] == fn and r["budget"] == headline)
             for fn in FUNCTIONS}
    wins = sum(by_fn.values())
    rec = {
        "algo": "de", "polish": polish, "dim": args.dim, "pop": args.pop,
        "n_islands": args.islands, "seeds": args.seeds,
        "backend": jax.default_backend(), "smoke": args.smoke,
        "rows": rows, "headline_budget": headline,
        "hybrid_wins_by_fn": by_fn,
        "hybrid_wins": wins, "n_functions": len(FUNCTIONS),
    }
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(f"\nhybrid DE+{args.polish} beats plain DE on {wins}/{len(FUNCTIONS)}"
          f" functions at equal eval budget -> {args.out}")
    if wins < args.min_wins:
        raise SystemExit(f"hybrid won only {wins} functions (< {args.min_wins})")


if __name__ == "__main__":
    main()
