"""Service soak: p50/p99 submit-to-result latency for concurrent mixed-shape
clients, blocking baseline (``workers=0``, the pre-§12 service: one global op
lock, flushes inline) vs the bounded worker pool — with and without an
injected slow bucket. Writes ``BENCH_service.json`` (CI uploads the --smoke
variant).

    PYTHONPATH=src python benchmarks/service.py            # full
    PYTHONPATH=src python benchmarks/service.py --smoke    # CI-sized

The slow bucket is injected through the scheduler's fault hook: every sync
round of one designated shape-class sleeps a few milliseconds, standing in
for a genuinely expensive objective. In the blocking baseline that bucket's
flush runs inline under the service op lock, so every other client's
submit/result stalls behind it and tail latency explodes; with the pool the
slow bucket pins one worker while fast buckets drain through the others.
The acceptance gate (full mode) is that the pool beats the baseline on p99
and wall time under slow-bucket injection.

Client threads drive ``OptimizationService.handle`` in-process — the same
entry point both the stdin and TCP front-ends call — so the measurement is
service-layer scheduling, not socket plumbing.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import numpy as np

from repro.core import OptRequest, ShapeBucketScheduler
from repro.launch.opt_serve import OptimizationService

FAST_SHAPES = [
    dict(fn="sphere", algo="de", dim=4, pop=16, n_islands=2, sync_every=5,
         max_evals=2_000, migration="ring"),
    dict(fn="rastrigin", algo="pso", dim=6, pop=16, n_islands=2, sync_every=5,
         max_evals=2_000, migration="ring"),
    dict(fn="rosenbrock", algo="de", dim=8, pop=32, n_islands=2, sync_every=5,
         max_evals=4_000, migration="ring"),
]


def _slow_shape(rounds: int) -> dict:
    # sync_every=1 => one hook call (and one injected sleep) per 32-eval round
    return dict(fn="rastrigin", algo="de", dim=5, pop=16, n_islands=2,
                sync_every=1, max_evals=32 + 32 * rounds, migration="ring")


def run_scenario(workers: int, slow: bool, n_threads: int, jobs_per_thread: int,
                 slow_rounds: int, slow_sleep_ms: float) -> dict:
    """One (mode, injection) cell: returns latency percentiles + wall time."""
    slow_key = OptRequest.from_dict(_slow_shape(slow_rounds)).shape_class()

    def hook(key, r):
        if key == slow_key:
            time.sleep(slow_sleep_ms / 1e3)

    sched = ShapeBucketScheduler(workers=workers,
                                 fault_hook=hook if slow else None)
    svc = OptimizationService(scheduler=sched, max_batch=8, flush_ms=10.0)

    # warm the compile caches so the measurement is scheduling, not XLA
    for i, shape in enumerate(FAST_SHAPES + ([_slow_shape(2)] if slow else [])):
        r = svc.handle({"op": "submit", "request": dict(shape, seed=900 + i)})
        svc.handle({"op": "result", "id": r["id"]})

    lat_ms, errors = [], []
    mu = threading.Lock()

    def client(t: int) -> None:
        for i in range(jobs_per_thread):
            req = dict(FAST_SHAPES[(t + i) % len(FAST_SHAPES)],
                       seed=1000 * t + i)
            t0 = time.perf_counter()
            sub = svc.handle({"op": "submit", "request": req})
            if "error" in sub:
                with mu:
                    errors.append(sub)
                continue
            out = svc.handle({"op": "result", "id": sub["id"]})
            dt = (time.perf_counter() - t0) * 1e3
            with mu:
                (lat_ms if out.get("status") == "done" else errors).append(dt)

    def slow_client() -> None:
        sub = svc.handle({"op": "submit",
                          "request": dict(_slow_shape(slow_rounds), seed=77)})
        svc.handle({"op": "flush"})
        svc.handle({"op": "result", "id": sub["id"]})

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    if slow:
        threads.insert(0, threading.Thread(target=slow_client))
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    sched.close()

    pct = (lambda q: round(float(np.percentile(lat_ms, q)), 2)) if lat_ms \
        else (lambda q: None)
    n = len(lat_ms)
    return {
        "mode": "pool" if workers else "blocking",
        "workers": workers,
        "slow_bucket": slow,
        "n_clients": n_threads + (1 if slow else 0),
        "jobs": n,
        "errors": len(errors),
        "p50_ms": pct(50), "p90_ms": pct(90), "p99_ms": pct(99),
        "mean_ms": round(float(np.mean(lat_ms)), 2) if lat_ms else None,
        "max_ms": pct(100),
        "wall_s": round(wall, 3),
        "jobs_per_s": round(n / wall, 3) if n else 0.0,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized soak (fewer clients, shorter slow bucket)")
    ap.add_argument("--threads", type=int, default=10,
                    help="fast-lane client threads per scenario")
    ap.add_argument("--jobs", type=int, default=10,
                    help="requests per client thread")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--slow-rounds", type=int, default=400,
                    help="sync rounds in the injected slow bucket")
    ap.add_argument("--slow-sleep-ms", type=float, default=10.0,
                    help="injected per-round sleep for the slow bucket")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()

    if args.smoke:
        args.threads, args.jobs, args.slow_rounds = 4, 3, 60

    scenarios = []
    for workers in (0, args.workers):
        for slow in (False, True):
            rec = run_scenario(workers, slow, args.threads, args.jobs,
                               args.slow_rounds, args.slow_sleep_ms)
            print(json.dumps(rec), flush=True)
            scenarios.append(rec)

    by = {(r["mode"], r["slow_bucket"]): r for r in scenarios}
    blocking, pool = by[("blocking", True)], by[("pool", True)]
    report = {
        "backend": jax.default_backend(),
        "smoke": args.smoke,
        "requests_total": sum(r["jobs"] for r in scenarios),
        "scenarios": scenarios,
        "slow_bucket_p99_speedup": round(blocking["p99_ms"] / pool["p99_ms"], 2),
        "slow_bucket_wall_speedup": round(blocking["wall_s"] / pool["wall_s"], 2),
        "pool_beats_blocking_with_slow_bucket":
            pool["p99_ms"] < blocking["p99_ms"]
            and pool["wall_s"] < blocking["wall_s"],
    }
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")
    print(json.dumps({k: v for k, v in report.items() if k != "scenarios"},
                     indent=2))
    if sum(r["errors"] for r in scenarios):
        raise SystemExit("soak lost responses")
    if not args.smoke and not report["pool_beats_blocking_with_slow_bucket"]:
        raise SystemExit("worker pool failed to beat the blocking baseline "
                         "under slow-bucket injection")


if __name__ == "__main__":
    main()
