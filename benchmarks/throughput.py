"""Scheduler throughput: jobs/sec through the shape-bucketed scheduler vs
naive sequential ``IslandOptimizer.minimize`` calls, for one bucket of
same-shaped jobs. Writes ``BENCH_scheduler.json`` (the repo's perf
trajectory artifact; CI uploads the --smoke variant).

    PYTHONPATH=src python benchmarks/throughput.py            # full
    PYTHONPATH=src python benchmarks/throughput.py --smoke    # CI-sized

The sequential baseline is what a client without the service would do: one
optimizer per request, one dispatch (and one XLA compile) per job. The
scheduler packs all jobs into a single jitted jobs-axis run, so N jobs cost
one compile + one dispatch; a second, warm flush isolates steady-state
dispatch throughput from compile amortization. Both paths draw the same
per-seed key chain, so the benchmark also asserts bit-identical results.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, OptRequest,
                        ShapeBucketScheduler)
from repro.functions import get


def bench(n_jobs: int, fn: str, algo: str, dim: int, pop: int, n_islands: int,
          max_evals: int) -> dict:
    f = get(fn, dim)
    mk = lambda seed: OptRequest(fn=fn, algo=algo, dim=dim, pop=pop,
                                 n_islands=n_islands, max_evals=max_evals,
                                 migration="ring", seed=seed)

    # -- naive sequential: fresh optimizer + dispatch per request ----------
    t0 = time.perf_counter()
    seq = []
    for s in range(n_jobs):
        cfg = IslandConfig(n_islands=n_islands, pop=pop, dim=dim,
                           migration="ring", max_evals=max_evals)
        opt = IslandOptimizer(ALGORITHMS[algo], cfg)
        seq.append(opt.minimize(f, jax.random.PRNGKey(s)))
    t_seq = time.perf_counter() - t0

    # -- scheduler: one bucket, one dispatch (cold: includes compile) ------
    sched = ShapeBucketScheduler()
    ids = [sched.submit(mk(s)) for s in range(n_jobs)]
    t0 = time.perf_counter()
    sched.flush()
    batched = [sched.result(i).result for i in ids]
    t_cold = time.perf_counter() - t0

    # -- warm flush: same bucket, fresh seeds, compiled program reused -----
    ids2 = [sched.submit(mk(s + n_jobs)) for s in range(n_jobs)]
    t0 = time.perf_counter()
    sched.flush()
    for i in ids2:
        sched.result(i)
    t_warm = time.perf_counter() - t0

    identical = all(b.value == s.value and b.n_evals == s.n_evals
                    for b, s in zip(batched, seq))
    return {
        "n_jobs": n_jobs, "fn": fn, "algo": algo, "dim": dim, "pop": pop,
        "n_islands": n_islands, "max_evals": max_evals,
        "backend": jax.default_backend(),
        "t_sequential_s": round(t_seq, 4),
        "t_scheduler_s": round(t_cold, 4),
        "t_scheduler_warm_s": round(t_warm, 4),
        "jobs_per_s_sequential": round(n_jobs / t_seq, 3),
        "jobs_per_s_scheduler": round(n_jobs / t_cold, 3),
        "jobs_per_s_scheduler_warm": round(n_jobs / t_warm, 3),
        "speedup": round(t_seq / t_cold, 3),
        "speedup_warm": round(t_seq / t_warm, 3),
        "bit_identical_to_sequential": identical,
        "dispatches": sched.n_dispatches,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problem (same 16-job bucket, tiny budget)")
    ap.add_argument("--jobs", type=int, default=16)
    ap.add_argument("--fn", default="rastrigin")
    ap.add_argument("--algo", default="de")
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--evals", type=int, default=40_000)
    ap.add_argument("--out", default="BENCH_scheduler.json")
    args = ap.parse_args()

    if args.smoke:
        args.dim, args.pop, args.islands, args.evals = 8, 32, 2, 4_000

    rec = bench(args.jobs, args.fn, args.algo, args.dim, args.pop,
                args.islands, args.evals)
    rec["smoke"] = args.smoke
    with open(args.out, "w") as fh:
        json.dump(rec, fh, indent=2)
        fh.write("\n")
    print(json.dumps(rec, indent=2))
    if not rec["bit_identical_to_sequential"]:
        raise SystemExit("scheduler results diverged from sequential runs")


if __name__ == "__main__":
    main()
