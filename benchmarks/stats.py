"""Paired statistical tests used by Fig. 4: sign test, Wilcoxon signed-rank,
and Student t — implemented from scratch (offline container, no scipy)."""
from __future__ import annotations

import math

import numpy as np


def sign_test(a: np.ndarray, b: np.ndarray, alpha: float = 0.05):
    """Two-sided exact binomial sign test on paired samples (a vs b).
    Returns (winner, significant): winner 'a' if a tends to be LOWER."""
    diff = a - b
    n_pos = int(np.sum(diff > 0))
    n_neg = int(np.sum(diff < 0))
    n = n_pos + n_neg
    if n == 0:
        return "tie", False
    k = min(n_pos, n_neg)
    # P(X <= k) for X ~ Bin(n, 1/2), two-sided
    p = sum(math.comb(n, i) for i in range(k + 1)) / 2 ** n * 2
    winner = "a" if n_neg > n_pos else ("b" if n_pos > n_neg else "tie")
    return winner, p < alpha


def signed_rank_test(a: np.ndarray, b: np.ndarray, alpha: float = 0.05):
    """Wilcoxon signed-rank with normal approximation (ties dropped)."""
    diff = a - b
    diff = diff[diff != 0]
    n = diff.size
    if n == 0:
        return "tie", False
    ranks = np.empty(n)
    order = np.argsort(np.abs(diff))
    sorted_abs = np.abs(diff)[order]
    # average ranks for ties
    i = 0
    while i < n:
        j = i
        while j + 1 < n and sorted_abs[j + 1] == sorted_abs[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2 + 1
        i = j + 1
    w_pos = float(np.sum(ranks[diff > 0]))
    w_neg = float(np.sum(ranks[diff < 0]))
    w = min(w_pos, w_neg)
    mu = n * (n + 1) / 4
    sigma = math.sqrt(n * (n + 1) * (2 * n + 1) / 24)
    if sigma == 0:
        return "tie", False
    z = (w - mu) / sigma
    p = 2 * 0.5 * math.erfc(abs(z) / math.sqrt(2))
    winner = "a" if w_neg > w_pos else ("b" if w_pos > w_neg else "tie")
    return winner, p < alpha


def t_test(a: np.ndarray, b: np.ndarray, alpha: float = 0.05):
    """Paired t-test with a normal-tail approximation for the p-value."""
    d = a - b
    n = d.size
    if n < 2 or np.std(d, ddof=1) == 0:
        return "tie", False
    t = float(np.mean(d) / (np.std(d, ddof=1) / math.sqrt(n)))
    # normal approximation of the t distribution tail (n small -> conservative)
    p = 2 * 0.5 * math.erfc(abs(t) / math.sqrt(2))
    winner = "a" if np.mean(d) < 0 else "b"
    return winner, p < alpha
