"""Fused Particle-Swarm generation — Pallas TPU kernel.

One grid step carries a (pop_block, dim) particle tile through the paper's
whole DPSO inner loop in VMEM: velocity update (inertia w + cognitive fp +
social fg), velocity clamp, position clip, shifted objective evaluation (the
shared ``bench_eval._eval_tile`` bodies) and the per-particle personal-best
selection — writing back positions, velocities, fitness and the updated
pbest/pbest_f in one pass. The unfused XLA path materializes r1/r2 products,
velocity, position and fitness as separate HBM arrays; here the population
makes one HBM round-trip per generation.

The island-level gbest reduction (argmin over pbest_f) stays in XLA: it is a
cross-tile reduction over O(P) scalars, negligible next to the O(P*D)
evaluation the kernel fuses. Random draws r1/r2 are made by the caller with
the same key discipline as ``core.pso.gen``, so fused and unfused paths are
bit-comparable on a fixed seed.

Tile shapes resolve via ``kernels.autotune``; pad rows from the pop_block
round-up are masked out of pbest selection and surface +inf fitness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig
from repro.kernels.bench_eval import EVAL_TAGS, _eval_tile, _row_index


def _kernel(x_ref, v_ref, pb_ref, pbf_ref, r1_ref, r2_ref, g_ref, shift_ref,
            nx_ref, nv_ref, nf_ref, npb_ref, npbf_ref, *, fn: str, dim: int,
            bias: float, w: float, fp: float, fg: float, vmax: float,
            lo: float, hi: float, n_rows: int):
    x = x_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    pb = pb_ref[...].astype(jnp.float32)
    pbf = pbf_ref[...].astype(jnp.float32)             # (P, 1)
    r1 = r1_ref[...].astype(jnp.float32)
    r2 = r2_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)                 # (1, Dp) gbest
    shift = shift_ref[...].astype(jnp.float32)         # (1, Dp)

    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = lane < dim
    nv = w * v + fp * r1 * (pb - x) + fg * r2 * (g - x)
    nv = jnp.where(valid, jnp.clip(nv, -vmax, vmax), 0.0)
    nx = jnp.where(valid, jnp.clip(x + nv, lo, hi), 0.0)

    fit = _eval_tile(nx - shift, fn, dim, bias)
    row_ok = _row_index(x.shape[0]) < n_rows
    imp = (fit < pbf[:, 0]) & row_ok
    npb = jnp.where(imp[:, None], nx, pb)
    npbf = jnp.where(imp, fit, pbf[:, 0])

    nx_ref[...] = nx.astype(nx_ref.dtype)
    nv_ref[...] = nv.astype(nv_ref.dtype)
    nf_ref[...] = jnp.where(row_ok, fit, jnp.inf)[:, None].astype(nf_ref.dtype)
    npb_ref[...] = npb.astype(npb_ref.dtype)
    npbf_ref[...] = jnp.where(row_ok, npbf, jnp.inf)[:, None].astype(
        npbf_ref.dtype)


def pso_step(x: jax.Array, v: jax.Array, pbest: jax.Array, pbest_f: jax.Array,
             r1: jax.Array, r2: jax.Array, gbest: jax.Array,
             fn: str = "sphere", shift: jax.Array | None = None,
             bias: float = 0.0, w: float = 0.6, fp: float = 1.0,
             fg: float = 1.0, vmax: float = float("inf"), lo: float = -100.0,
             hi: float = 100.0, pop_block: int | None = None, *,
             interpret: bool | None = None,
             kernel_cfg: KernelConfig | None = None):
    """One fused PSO generation.

    x, v, pbest, r1, r2: (P, D) f32; pbest_f: (P,); gbest: (D,) — the
    island's incumbent position. Returns (new_x, new_v, fit, new_pbest,
    new_pbest_f); the gbest/best_val argmin update stays with the caller.
    """
    assert fn in EVAL_TAGS, fn
    P, D = x.shape
    cfg = autotune.resolve(
        autotune.merge(kernel_cfg, pop_block=pop_block, interpret=interpret),
        "pso_step", P, D, tag=fn)
    dt = jnp.dtype(cfg.dtype)
    Dp = max(cfg.dim_pad, (D + 127) // 128 * 128)
    Pp = (P + cfg.pop_block - 1) // cfg.pop_block * cfg.pop_block
    padPD = lambda a: jnp.pad(a, ((0, Pp - P), (0, Dp - D))).astype(dt)
    padD = lambda a: jnp.pad(a, (0, Dp - D)).astype(dt)[None, :]
    s = jnp.zeros((1, Dp), dt) if shift is None else padD(shift)
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias, w=w, fp=fp,
                               fg=fg, vmax=vmax, lo=lo, hi=hi, n_rows=P)
    row = lambda i: (i, 0)
    vec = pl.BlockSpec((cfg.pop_block, Dp), row)
    col = pl.BlockSpec((cfg.pop_block, 1), row)
    bcast = pl.BlockSpec((1, Dp), lambda i: (0, 0))
    nx, nv, nf, npb, npbf = pl.pallas_call(
        kernel,
        grid=(Pp // cfg.pop_block,),
        in_specs=[vec, vec, vec, col, vec, vec, bcast, bcast],
        out_specs=[vec, vec, col, vec, col],
        out_shape=[jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32)],
        interpret=cfg.interpret,
    )(padPD(x), padPD(v), padPD(pbest),
      jnp.pad(pbest_f, (0, Pp - P))[:, None], padPD(r1), padPD(r2),
      padD(gbest), s)
    return (nx[:P, :D].astype(x.dtype), nv[:P, :D].astype(v.dtype),
            nf[:P, 0], npb[:P, :D].astype(pbest.dtype), npbf[:P, 0])
