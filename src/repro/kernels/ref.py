"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (BH, S, hd); k, v: (BH, T, hd)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones_like(s, bool)
    if causal:
        ok &= (qp >= kp)[None]
    if window > 0:
        ok &= ((qp - kp) < window)[None]
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(xh, dt, A, Bm, Cm):
    """Sequential SSD recurrence. xh: (BH, S, P); dt: (BH, S); A: (BH,);
    Bm, Cm: (BH, S, N). Returns (BH, S, P)."""
    def one(x, d, a, B, C):
        def step(state, inp):
            xt, dt_t, bt, ct = inp
            dA = jnp.exp(dt_t * a)
            state = state * dA + jnp.outer(bt, xt * dt_t)
            return state, ct @ state
        S, P = x.shape
        N = B.shape[-1]
        state0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, state0,
                             (x.astype(jnp.float32), d.astype(jnp.float32),
                              B.astype(jnp.float32), C.astype(jnp.float32)))
        return ys

    return jax.vmap(one)(xh, dt, A, Bm, Cm).astype(xh.dtype)


def bench_eval_ref(pop, fn, shift=None, bias=0.0):
    from repro.functions import benchmarks as bm
    x = pop.astype(jnp.float32)
    if shift is not None:
        x = x - shift
    if fn == "shifted_rosenbrock":
        return bm.rosenbrock(x + 1.0) + bias
    return getattr(bm, fn)(x) + bias


def de_step_ref(pop, fit, idx_abc, u, jrand, fn="sphere", shift=None,
                bias=0.0, w=0.5, px=0.2, lo=-100.0, hi=100.0):
    P, D = pop.shape
    pa, pb, pc = pop[idx_abc[0]], pop[idx_abc[1]], pop[idx_abc[2]]
    mutant = jnp.clip(pa + w * (pb - pc), lo, hi)
    cross = (u < px) | (jnp.arange(D)[None, :] == jrand[:, None])
    trial = jnp.where(cross, mutant, pop)
    tfit = bench_eval_ref(trial, fn, shift, bias)
    better = tfit <= fit
    return (jnp.where(better[:, None], trial, pop),
            jnp.where(better, tfit, fit))
