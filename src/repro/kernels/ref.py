"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (BH, S, hd); k, v: (BH, T, hd)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones_like(s, bool)
    if causal:
        ok &= (qp >= kp)[None]
    if window > 0:
        ok &= ((qp - kp) < window)[None]
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(xh, dt, A, Bm, Cm):
    """Sequential SSD recurrence. xh: (BH, S, P); dt: (BH, S); A: (BH,);
    Bm, Cm: (BH, S, N). Returns (BH, S, P)."""
    def one(x, d, a, B, C):
        def step(state, inp):
            xt, dt_t, bt, ct = inp
            dA = jnp.exp(dt_t * a)
            state = state * dA + jnp.outer(bt, xt * dt_t)
            return state, ct @ state
        S, P = x.shape
        N = B.shape[-1]
        state0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, state0,
                             (x.astype(jnp.float32), d.astype(jnp.float32),
                              B.astype(jnp.float32), C.astype(jnp.float32)))
        return ys

    return jax.vmap(one)(xh, dt, A, Bm, Cm).astype(xh.dtype)


def bench_eval_ref(pop, fn, shift=None, bias=0.0):
    from repro.functions import benchmarks as bm
    x = pop.astype(jnp.float32)
    if shift is not None:
        x = x - shift
    if fn == "shifted_rosenbrock":
        return bm.rosenbrock(x + 1.0) + bias
    return getattr(bm, fn)(x) + bias


def de_step_ref(pop, fit, idx_abc, u, jrand, fn="sphere", shift=None,
                bias=0.0, w=0.5, px=0.2, lo=-100.0, hi=100.0):
    P, D = pop.shape
    pa, pb, pc = pop[idx_abc[0]], pop[idx_abc[1]], pop[idx_abc[2]]
    mutant = jnp.clip(pa + w * (pb - pc), lo, hi)
    cross = (u < px) | (jnp.arange(D)[None, :] == jrand[:, None])
    trial = jnp.where(cross, mutant, pop)
    tfit = bench_eval_ref(trial, fn, shift, bias)
    better = tfit <= fit
    return (jnp.where(better[:, None], trial, pop),
            jnp.where(better, tfit, fit))


def pso_step_ref(x, v, pbest, pbest_f, r1, r2, gbest, fn="sphere", shift=None,
                 bias=0.0, w=0.6, fp=1.0, fg=1.0, vmax=float("inf"),
                 lo=-100.0, hi=100.0):
    nv = w * v + fp * r1 * (pbest - x) + fg * r2 * (gbest[None, :] - x)
    nv = jnp.clip(nv, -vmax, vmax)
    nx = jnp.clip(x + nv, lo, hi)
    fit = bench_eval_ref(nx, fn, shift, bias)
    imp = fit < pbest_f
    return (nx, nv, fit, jnp.where(imp[:, None], nx, pbest),
            jnp.where(imp, fit, pbest_f))


def ga_step_ref(p1, p2, slot_pop, slot_f, cut, co, um, noise, fn="sphere",
                shift=None, bias=0.0, pc=0.7, pm=0.1, sigma_m=1.0,
                lo=-100.0, hi=100.0):
    N, D = p1.shape
    do_co = (co < pc)[:, None]
    mask = jnp.arange(D)[None, :] < cut[:, None]
    child = jnp.where(do_co & mask | ~do_co, p1, p2)
    child = child + jnp.where(um < pm, sigma_m * noise, 0.0)
    child = jnp.clip(child, lo, hi)
    cfit = bench_eval_ref(child, fn, shift, bias)
    take = cfit < slot_f
    return (jnp.where(take[:, None], child, slot_pop),
            jnp.where(take, cfit, slot_f), take)


def eval_select_ref(pop, fit, trial, thresh=None, fn="sphere", shift=None,
                    bias=0.0):
    tfit = bench_eval_ref(trial, fn, shift, bias)
    dF = tfit - fit
    th = jnp.zeros_like(fit) if thresh is None else thresh
    acc = (dF <= 0.0) | (dF < th)
    return (jnp.where(acc[:, None], trial, pop),
            jnp.where(acc, tfit, fit), acc)
