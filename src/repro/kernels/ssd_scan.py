"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (batch*heads, n_chunks); the chunk axis is the minor (sequential) grid
dimension, so the inter-chunk recurrent state (N, P) lives in VMEM scratch and
flows across chunk steps — the TPU-native replacement for the CUDA
implementation's fused warp-level scan. Within a chunk everything is (Q, N) /
(Q, Q) / (Q, P) matmuls on the MXU plus a cumulative-sum decay.

VMEM per step at Q=128, N=128, P=64: x(QP) + B,C(QN) + L(QQ) + state(NP)
~ 0.25 MB f32 — tiny; double buffering and bigger Q are free wins on TPU.

Validated against ref.ssd_ref with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, 1)
    A = a_ref[0].astype(jnp.float32)        # (1,) scalar decay rate (per head)
    B = b_ref[0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0].astype(jnp.float32)        # (Q, N)

    dA = dt[:, 0] * A[0]                    # (Q,) negative
    seg = jnp.cumsum(dA)                    # within-chunk cumulative log-decay
    total = seg[-1]

    # intra-chunk: (C B^T * L) @ (x dt)
    li = seg[:, None] - seg[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(mask, jnp.exp(li), 0.0)
    xdt = x * dt                             # (Q, P)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot(cb * L, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: y += C exp(seg) @ state ; state' = e^total state + B^T(decay x)
    y += jax.lax.dot(C * jnp.exp(seg)[:, None], state_ref[...],
                     preferred_element_type=jnp.float32)
    decay_to_end = jnp.exp(total - seg)[:, None]           # (Q, 1)
    state_ref[...] = (state_ref[...] * jnp.exp(total)
                      + jax.lax.dot_general(B, xdt * decay_to_end,
                                            (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int = 128, *,
             interpret: bool = False) -> jax.Array:
    """xh: (BH, S, P); dt: (BH, S); A: (BH,); Bm, Cm: (BH, S, N).
    S must be a multiple of ``chunk``. Returns y: (BH, S, P)."""
    BH, S, P = xh.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, "pad sequence to the chunk size"
    nC = S // chunk
    kernel = functools.partial(_kernel, Q=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nC),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dt[..., None], A[:, None], Bm, Cm)
