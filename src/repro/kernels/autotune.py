"""Roofline-driven kernel autotuning — shape-specialized Pallas tile configs.

The fused kernels (``bench_eval``, ``de_step``, ``pso_step``, ``ga_step``,
``eval_select``) used to hard-code their tile shapes (``pop_block=8`` for
evaluation, ``128`` for the DE step). This module replaces those constants
with a per-op, shape-specialized config chosen by the analytic memory model
the repo already carries:

  * candidate ``(pop_block, dim_pad)`` configs are scored with the roofline
    terms of ``parallel.roofline`` (compute = FLOPs / peak, memory = HBM
    bytes / bandwidth — same constants the dry-run analyzer uses) built from
    a per-kernel operand profile (``KIND_PROFILES``);
  * VMEM feasibility comes from ``parallel.memmodel.pallas_tile_bytes`` (the
    double-buffered working set of one grid step must fit the budget);
  * off-TPU the kernels run in Pallas *interpret* mode, where every grid
    step costs a host-visible dispatch — the score adds a per-step overhead
    term, so interpret-mode configs converge to few large tiles while TPU
    configs keep tiles VMEM-sized for pipelining;
  * an optional short *measured* sweep (``measure=True``) times the real
    kernel entry over the feasible candidates and overrides the model.

Chosen configs are cached per shape-class — ``(kind, P, D, eval tag, dtype,
platform, interpret)`` — alongside the compiled-program caches the scheduler
keeps, so a shape-class is tuned once per process and every later build is a
cache hit (``tests/test_autotune.py`` enforces no re-tune). Function-keyed
lookups (``choose_for``) key on ``Function.cache_token()`` — the GC-stable
identity used by every other compiled-program cache in the repo — so a
recycled objective ``id()`` can never serve a config tuned for a dead shift.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

import jax

from repro.models.config import HBM_BW, PEAK_FLOPS_BF16
from repro.parallel.memmodel import pallas_tile_bytes
from repro.parallel.roofline import Roofline

# -- the threaded config -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """How the Pallas kernel layer tiles and runs — one config threaded from
    ``ExecutorConfig.kernel`` through every kernel entry point.

    ``None`` fields resolve at call time: ``pop_block``/``dim_pad`` from the
    autotuner (per shape-class, cached), ``interpret`` from the platform
    (interpret mode off-TPU). ``dtype`` is the HBM storage dtype of the
    population tiles (compute is always f32 in-kernel); non-f32 dtypes halve
    memory traffic at a parity-tolerance cost.
    """

    pop_block: int | None = None
    dim_pad: int | None = None
    interpret: bool | None = None
    dtype: str = "float32"

    def itemsize(self) -> int:
        """Bytes per element of the HBM storage dtype."""
        return int(np.dtype(self.dtype).itemsize)


# -- per-kernel operand profiles ---------------------------------------------

@dataclasses.dataclass(frozen=True)
class KindProfile:
    """Operand counts of one fused kernel, per grid step.

    ``vec`` counts ``(pop_block, dim_pad)`` tiles moved between HBM and VMEM
    (inputs + outputs), ``row`` the ``(pop_block,)`` per-row operands,
    ``bcast`` the ``(dim_pad,)`` broadcast rows (shift, gbest), and
    ``var_flops`` the non-evaluation arithmetic per element (variation +
    selection math).
    """

    vec_in: int
    vec_out: int
    row: int = 2
    bcast: int = 1
    var_flops: int = 0


KIND_PROFILES: dict[str, KindProfile] = {
    "bench_eval": KindProfile(vec_in=1, vec_out=0, row=1, bcast=1),
    "de_step": KindProfile(vec_in=5, vec_out=1, row=4, bcast=1, var_flops=7),
    "pso_step": KindProfile(vec_in=5, vec_out=3, row=3, bcast=2, var_flops=11),
    "ga_step": KindProfile(vec_in=5, vec_out=1, row=5, bcast=1, var_flops=8),
    "eval_select": KindProfile(vec_in=2, vec_out=1, row=3, bcast=1,
                               var_flops=2),
}

# Rough per-element FLOP weights of the ``_eval_tile`` bodies (transcendental
# ops counted ~4 flops). Only the *relative* magnitude vs the memory term
# matters for tile choice.
EVAL_FLOPS: dict[str, int] = {
    "sphere": 2, "rastrigin": 12, "rosenbrock": 8, "shifted_rosenbrock": 9,
    "ackley": 14, "griewank": 16, "schwefel": 14, "levy": 22,
    "dropwave": 14, "michalewicz": 24,
}
_DEFAULT_EVAL_FLOPS = 12

# VMEM working-set budget per grid step (double-buffered), bytes. Real TPU
# cores expose ~16 MiB of VMEM; leave headroom for Mosaic's own scratch.
VMEM_BUDGET = 12 * 1024 * 1024
# Host-visible cost of one interpret-mode grid step (the Pallas interpreter
# re-enters per step); measured ~tens of microseconds on this container.
INTERPRET_STEP_OVERHEAD_S = 2e-5
# Candidate tile heights swept by the model.
POP_BLOCKS = (8, 16, 32, 64, 128, 256, 512, 1024)


def round_up(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= ``n``."""
    return -(-n // mult) * mult


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` flag: explicit value, else off-TPU auto."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class Prediction:
    """One scored candidate: its roofline terms plus the tiling metadata the
    score adds on top (grid steps, VMEM working set, total predicted time)."""

    pop_block: int
    dim_pad: int
    roofline: Roofline
    n_grid: int
    tile_bytes: int
    t_total: float

    @property
    def feasible(self) -> bool:
        """Whether the double-buffered tile working set fits the VMEM budget."""
        return self.tile_bytes <= VMEM_BUDGET


def predict(kind: str, P: int, D: int, pop_block: int, dim_pad: int,
            tag: str = "sphere", itemsize: int = 4,
            interpret: bool = False) -> Prediction:
    """Roofline prediction for one ``(pop_block, dim_pad)`` candidate.

    FLOPs and HBM bytes come from the kernel's operand profile over the
    padded ``(Pp, dim_pad)`` problem; time terms use the same peak numbers as
    ``parallel.roofline.analyze``. Interpret mode adds a per-grid-step
    dispatch overhead, which is what drives off-TPU configs toward one big
    tile while VMEM keeps TPU tiles small.
    """
    prof = KIND_PROFILES[kind]
    Pp = round_up(P, pop_block)
    n_grid = Pp // pop_block
    eflops = EVAL_FLOPS.get(tag, _DEFAULT_EVAL_FLOPS)
    elems = Pp * dim_pad
    flops = float(elems) * (prof.var_flops + eflops)
    hbm = float(
        (prof.vec_in + prof.vec_out) * elems * itemsize
        + prof.row * Pp * 4
        + prof.bcast * dim_pad * 4
    )
    t_c = flops / PEAK_FLOPS_BF16
    t_m = hbm / HBM_BW
    tile = pallas_tile_bytes(
        prof.vec_in + prof.vec_out, pop_block, dim_pad,
        n_row=prof.row, n_bcast=prof.bcast, itemsize=4,  # VMEM tiles are f32
        double_buffered=True)
    roof = Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=0.0, t_compute=t_c,
        t_memory=t_m, t_collective=0.0,
        bottleneck="compute" if t_c >= t_m else "memory",
        peak_bytes=float(tile))
    t = max(t_c, t_m)
    if interpret:
        t += n_grid * INTERPRET_STEP_OVERHEAD_S
    return Prediction(pop_block=pop_block, dim_pad=dim_pad, roofline=roof,
                      n_grid=n_grid, tile_bytes=tile, t_total=t)


def candidates(P: int, D: int) -> list[tuple[int, int]]:
    """The ``(pop_block, dim_pad)`` grid the tuner scores: tile heights up to
    the padded population, lane-aligned dim paddings (the minimal 128-multiple
    and the next one up, so padding waste is scored rather than assumed)."""
    d0 = round_up(max(D, 1), 128)
    dims = [d0] if d0 > D + 128 else [d0, d0 + 128]
    pmax = round_up(max(P, 1), 8)
    blocks = sorted({min(b, pmax) for b in POP_BLOCKS})
    return [(b, d) for b in blocks for d in dims]


# -- the per-shape-class config cache ----------------------------------------

_CACHE: dict[tuple, KernelConfig] = {}
_FN_CACHE: dict[tuple, KernelConfig] = {}
_STATS = {"hits": 0, "misses": 0, "measured": 0}


def cache_stats() -> dict[str, int]:
    """Tuner cache counters (hits / misses / measured sweeps) — test hook."""
    return dict(_STATS)


def clear_cache() -> None:
    """Drop every cached config and reset counters (tests only)."""
    _CACHE.clear()
    _FN_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


def _shape_class(kind: str, P: int, D: int, tag: str, dtype: str,
                 interpret: bool, measure: bool) -> tuple:
    return (kind, P, D, tag, dtype, jax.default_backend(), interpret, measure)


def choose(kind: str, P: int, D: int, tag: str = "sphere", *,
           dtype: str = "float32", interpret: bool | None = None,
           measure: bool = False) -> KernelConfig:
    """The autotuned, fully-resolved :class:`KernelConfig` for one kernel
    shape-class.

    Scores every feasible ``(pop_block, dim_pad)`` candidate with
    :func:`predict` (optionally re-ranking the top candidates by a short
    measured sweep) and caches the winner per shape-class, so repeated builds
    — scheduler bucket flushes, benchmark loops, re-traces — never re-tune.
    """
    if kind not in KIND_PROFILES:
        raise KeyError(
            f"unknown kernel kind {kind!r}; known: {sorted(KIND_PROFILES)}")
    interp = default_interpret(interpret)
    key = _shape_class(kind, P, D, tag, dtype, interp, measure)
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        return hit
    _STATS["misses"] += 1
    itemsize = int(np.dtype(dtype).itemsize)
    preds = [predict(kind, P, D, b, d, tag=tag, itemsize=itemsize,
                     interpret=interp) for (b, d) in candidates(P, D)]
    feasible = [p for p in preds if p.feasible] or preds  # degenerate: best-effort
    feasible.sort(key=lambda p: (p.t_total, p.tile_bytes))
    best = feasible[0]
    if measure:
        best = _measured_best(kind, P, D, tag, feasible[:4], interp, dtype)
        _STATS["measured"] += 1
    cfg = KernelConfig(pop_block=best.pop_block, dim_pad=best.dim_pad,
                       interpret=interp, dtype=dtype)
    _CACHE[key] = cfg
    return cfg


def choose_for(f, kind: str, P: int, D: int, *,
               dtype: str = "float32", interpret: bool | None = None,
               measure: bool = False) -> KernelConfig:
    """:func:`choose` keyed by an objective's ``Function.cache_token()``.

    The maker-level entry (``de.make(fused=True)`` and friends) tunes against
    the *objective*, not a bare tag string; keying the memo on the GC-stable
    ``cache_token`` (not ``id(f)``) mirrors the executor/scheduler program
    caches, so a recycled object address can never alias a dead objective's
    config.
    """
    from repro.kernels import registry as kreg  # late: avoid import cycles
    interp = default_interpret(interpret)
    key = (kind, P, D, dtype, jax.default_backend(), interp, measure,
           *f.cache_token())
    hit = _FN_CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        return hit
    tag = kreg.get_spec(f.name).eval_tag
    cfg = choose(kind, P, D, tag, dtype=dtype, interpret=interpret,
                 measure=measure)
    _FN_CACHE[key] = cfg
    return cfg


def merge(cfg: KernelConfig | None, *, pop_block: int | None = None,
          dim_pad: int | None = None,
          interpret: bool | None = None) -> KernelConfig:
    """Overlay explicit per-call keyword overrides onto a (possibly ``None``)
    threaded config — explicit keywords win, then config fields, then the
    tuner fills whatever is still ``None`` via :func:`resolve`."""
    base = cfg if cfg is not None else KernelConfig()
    return dataclasses.replace(
        base,
        pop_block=pop_block if pop_block is not None else base.pop_block,
        dim_pad=dim_pad if dim_pad is not None else base.dim_pad,
        interpret=interpret if interpret is not None else base.interpret)


def resolve(cfg: KernelConfig | None, kind: str, P: int, D: int,
            tag: str = "sphere", interpret: bool | None = None,
            measure: bool = False) -> KernelConfig:
    """Fill a (possibly partial) :class:`KernelConfig` into a fully-resolved
    one — explicit fields win, missing fields come from the tuner cache.

    Every kernel entry point funnels through here, so a config threaded via
    ``ExecutorConfig.kernel`` reaches ``bench_eval``/``de_step``/``pso_step``
    /``ga_step``/``eval_select`` uniformly instead of each call site keeping
    its own keyword default.
    """
    cfg = cfg if cfg is not None else KernelConfig()
    interp = cfg.interpret if cfg.interpret is not None else interpret
    if cfg.pop_block is not None and cfg.dim_pad is not None:
        return KernelConfig(pop_block=cfg.pop_block, dim_pad=cfg.dim_pad,
                            interpret=default_interpret(interp),
                            dtype=cfg.dtype)
    tuned = choose(kind, P, D, tag, dtype=cfg.dtype, interpret=interp,
                   measure=measure)
    return KernelConfig(
        pop_block=cfg.pop_block if cfg.pop_block is not None else tuned.pop_block,
        dim_pad=cfg.dim_pad if cfg.dim_pad is not None else tuned.dim_pad,
        interpret=tuned.interpret, dtype=cfg.dtype)


# -- optional measured sweep -------------------------------------------------

def _measured_best(kind: str, P: int, D: int, tag: str,
                   preds: list[Prediction], interpret: bool,
                   dtype: str) -> Prediction:
    """Re-rank the model's top candidates by a short timed sweep of the real
    kernel entry (3 reps, best-of). Falls back to the model's pick when the
    kernel cannot run (e.g. unregistered tag in a unit test)."""
    try:
        runner = _make_runner(kind, P, D, tag, dtype)
    except Exception:
        return preds[0]
    best, best_t = preds[0], float("inf")
    for p in preds:
        try:
            t = _time_once(lambda: runner(p.pop_block, p.dim_pad, interpret))
        except Exception:
            continue
        if t < best_t:
            best, best_t = p, t
    return best


def _time_once(fn: Callable[[], None], reps: int = 3) -> float:
    fn()  # warm-up / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _make_runner(kind: str, P: int, D: int, tag: str, dtype: str):
    """A closure running one real kernel invocation on synthetic data."""
    import jax.numpy as jnp

    # Module imports, not package attributes: the package re-exports the entry
    # *functions* under the same names, which would shadow the modules here.
    import repro.kernels.bench_eval as _be
    import repro.kernels.de_step as _de
    import repro.kernels.eval_select as _es
    import repro.kernels.ga_step as _ga
    import repro.kernels.pso_step as _ps

    key = jax.random.PRNGKey(0)
    pop = jax.random.uniform(key, (P, D), minval=-1.0, maxval=1.0)
    fit = jnp.ones((P,), jnp.float32)

    def cfgk(b: int, d: int, interp: bool) -> KernelConfig:
        return KernelConfig(pop_block=b, dim_pad=d, interpret=interp,
                            dtype=dtype)

    if kind == "bench_eval":
        def run(b, d, interp):
            _be.bench_eval(pop, tag, kernel_cfg=cfgk(b, d, interp)
                           ).block_until_ready()
    elif kind == "eval_select":
        def run(b, d, interp):
            _es.eval_select(pop, fit, pop, fn=tag,
                            kernel_cfg=cfgk(b, d, interp)
                            )[1].block_until_ready()
    elif kind == "de_step":
        i = jnp.arange(P)
        idx = jnp.stack([(i + 1) % P, (i + 2) % P, (i + 3) % P])
        u = jnp.zeros((P, D), jnp.float32)
        jr = jnp.zeros((P,), jnp.int32)

        def run(b, d, interp):
            _de.de_step(pop, fit, idx, u, jr, fn=tag,
                        kernel_cfg=cfgk(b, d, interp))[1].block_until_ready()
    elif kind == "pso_step":
        z = jnp.zeros_like(pop)

        def run(b, d, interp):
            _ps.pso_step(pop, z, pop, fit, z, z, pop[0], fn=tag,
                         kernel_cfg=cfgk(b, d, interp))[2].block_until_ready()
    elif kind == "ga_step":
        z = jnp.zeros_like(pop)
        cut = jnp.ones((P,), jnp.int32)
        co = jnp.zeros((P,), jnp.float32)

        def run(b, d, interp):
            _ga.ga_step(pop, pop, pop, fit, cut, co, z, z, fn=tag,
                        kernel_cfg=cfgk(b, d, interp))[1].block_until_ready()
    else:  # pragma: no cover - guarded by KIND_PROFILES check in choose()
        raise KeyError(kind)
    return run
