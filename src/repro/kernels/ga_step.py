"""Fused Genetic-Algorithm offspring generation — Pallas TPU kernel.

One grid step carries a (pop_block, dim) offspring tile through the DGA inner
loop in VMEM: 1-pt crossover of the pre-gathered parents, per-allele Gaussian
mutation, box clipping, shifted objective evaluation (the shared
``bench_eval._eval_tile`` bodies) and the improve-the-slot placement test —
writing back the new slot contents (child where it beats the slot, the old
occupant otherwise) plus the take mask.

Cross-population work stays in XLA where it belongs: aging, roulette-wheel
parent sampling (``jax.random.categorical``), the argsort that picks the
worst slots, and the final scatter are all O(P)-scalar or gather/scatter ops
that cannot tile row-locally — mirroring ``de_step``'s pre-gathered-donor
design. The caller hands the kernel parent rows p1/p2, the slot occupants and
their fitness, and the per-row crossover/mutation draws (same key discipline
as ``core.ga.gen``, so fused and unfused paths are bit-comparable).

Tile shapes resolve via ``kernels.autotune``; pad rows from the pop_block
round-up never place (take=0) and surface +inf slot fitness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig
from repro.kernels.bench_eval import EVAL_TAGS, _eval_tile, _row_index


def _kernel(p1_ref, p2_ref, sp_ref, sf_ref, cut_ref, co_ref, um_ref, nz_ref,
            shift_ref, ns_ref, nf_ref, tk_ref, *, fn: str, dim: int,
            bias: float, pc: float, pm: float, sigma_m: float, lo: float,
            hi: float, n_rows: int):
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)
    slot = sp_ref[...].astype(jnp.float32)
    slot_f = sf_ref[...].astype(jnp.float32)           # (P, 1)
    cut = cut_ref[...]                                 # (P, 1) int32
    co = co_ref[...].astype(jnp.float32)               # (P, 1) uniforms
    um = um_ref[...].astype(jnp.float32)
    nz = nz_ref[...].astype(jnp.float32)               # raw N(0,1) draws
    shift = shift_ref[...].astype(jnp.float32)         # (1, Dp)

    lane = jax.lax.broadcasted_iota(jnp.int32, p1.shape, 1)
    valid = lane < dim
    do_co = co < pc
    child = jnp.where(do_co & (lane < cut) | ~do_co, p1, p2)
    child = child + jnp.where(um < pm, sigma_m * nz, 0.0)
    child = jnp.where(valid, jnp.clip(child, lo, hi), 0.0)

    cfit = _eval_tile(child - shift, fn, dim, bias)
    row_ok = _row_index(p1.shape[0]) < n_rows
    take = (cfit < slot_f[:, 0]) & row_ok
    nf = jnp.where(take, cfit, slot_f[:, 0])
    ns_ref[...] = jnp.where(take[:, None], child, slot).astype(ns_ref.dtype)
    nf_ref[...] = jnp.where(row_ok, nf, jnp.inf)[:, None].astype(nf_ref.dtype)
    tk_ref[...] = take[:, None].astype(tk_ref.dtype)


def ga_step(p1: jax.Array, p2: jax.Array, slot_pop: jax.Array,
            slot_f: jax.Array, cut: jax.Array, co: jax.Array, um: jax.Array,
            noise: jax.Array, fn: str = "sphere",
            shift: jax.Array | None = None, bias: float = 0.0,
            pc: float = 0.7, pm: float = 0.1, sigma_m: float = 1.0,
            lo: float = -100.0, hi: float = 100.0,
            pop_block: int | None = None, *, interpret: bool | None = None,
            kernel_cfg: KernelConfig | None = None):
    """One fused GA offspring wave over ``n_off`` rows.

    p1, p2: (N, D) pre-gathered parents; slot_pop/slot_f: the worst-slot
    occupants the offspring compete for; cut: (N,) 1-pt crossover positions;
    co: (N,) crossover-probability uniforms; um, noise: (N, D) mutation
    uniforms / N(0,1) draws. Returns (new_slot, new_slot_f, take) — the
    caller scatters them back at its slot indices and updates age/liveness
    from ``take``.
    """
    assert fn in EVAL_TAGS, fn
    P, D = p1.shape
    cfg = autotune.resolve(
        autotune.merge(kernel_cfg, pop_block=pop_block, interpret=interpret),
        "ga_step", P, D, tag=fn)
    dt = jnp.dtype(cfg.dtype)
    Dp = max(cfg.dim_pad, (D + 127) // 128 * 128)
    Pp = (P + cfg.pop_block - 1) // cfg.pop_block * cfg.pop_block
    padPD = lambda a: jnp.pad(a, ((0, Pp - P), (0, Dp - D))).astype(dt)
    padP = lambda a: jnp.pad(a, (0, Pp - P))[:, None]
    s = (jnp.zeros((1, Dp), dt) if shift is None
         else jnp.pad(shift, (0, Dp - D)).astype(dt)[None, :])
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias, pc=pc, pm=pm,
                               sigma_m=sigma_m, lo=lo, hi=hi, n_rows=P)
    row = lambda i: (i, 0)
    vec = pl.BlockSpec((cfg.pop_block, Dp), row)
    col = pl.BlockSpec((cfg.pop_block, 1), row)
    ns, nf, tk = pl.pallas_call(
        kernel,
        grid=(Pp // cfg.pop_block,),
        in_specs=[vec, vec, vec, col, col, col, vec, vec,
                  pl.BlockSpec((1, Dp), lambda i: (0, 0))],
        out_specs=[vec, col, col],
        out_shape=[jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32)],
        interpret=cfg.interpret,
    )(padPD(p1), padPD(p2), padPD(slot_pop), padP(slot_f),
      padP(cut).astype(jnp.int32), padP(co), padPD(um), padPD(noise), s)
    return (ns[:P, :D].astype(p1.dtype), nf[:P, 0], tk[:P, 0] > 0.5)
