"""Fused population evaluation — Pallas TPU kernel.

The paper's hot loop: every meta-heuristic spends its 1M-evaluation budget in
``f(pop)`` (Fig. 4 protocol). This kernel evaluates a (pop_block, dim) tile per
grid step entirely in VMEM — one HBM read of the population, no intermediate
arrays — for the §V testbed functions listed in ``kernels.registry`` (sphere /
rastrigin / rosenbrock / ackley / griewank / schwefel / levy / dropwave /
michalewicz, incl. the CEC'2008 shifted Rosenbrock via a shift operand).

dim is carried whole per tile (the paper's 1000-D padded to 1024 lane-aligned);
pop_block=8 rows x 1024 dims x 4B = 32 KB live VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Objective bodies _eval_tile implements. ``kernels.registry`` maps function
# *names* to one of these tags (several names may share a tag); this tuple is
# the ground truth for what the kernel itself can evaluate.
EVAL_TAGS = (
    "sphere", "rastrigin", "rosenbrock", "ackley", "shifted_rosenbrock",
    "griewank", "schwefel", "levy", "dropwave", "michalewicz",
)


def _eval_tile(x: jax.Array, fn: str, dim: int, bias: float) -> jax.Array:
    """x: (P, Dp) f32 with zero padding beyond ``dim``; returns (P,)."""
    Dp = x.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = lane < dim
    if fn in ("rosenbrock", "shifted_rosenbrock"):
        if fn == "shifted_rosenbrock":
            x = jnp.where(valid, x + 1.0, 0.0)   # z = x - o + 1 (o applied outside)
        x0 = x
        x1 = jnp.concatenate([x[:, 1:], jnp.zeros_like(x[:, :1])], axis=1)
        pair = lane < (dim - 1)
        t = jnp.where(pair, 100.0 * (x1 - x0 * x0) ** 2 + (1.0 - x0) ** 2, 0.0)
        return t.sum(axis=1) + bias
    if fn == "sphere":
        return jnp.where(valid, x * x, 0.0).sum(axis=1) + bias
    if fn == "rastrigin":
        t = jnp.where(valid, x * x - 10.0 * jnp.cos(2.0 * jnp.pi * x) + 10.0, 0.0)
        return t.sum(axis=1) + bias
    if fn == "ackley":
        s1 = jnp.where(valid, x * x, 0.0).sum(axis=1) / dim
        s2 = jnp.where(valid, jnp.cos(2.0 * jnp.pi * x), 0.0).sum(axis=1) / dim
        return (-20.0 * jnp.exp(-0.2 * jnp.sqrt(s1)) - jnp.exp(s2)
                + 20.0 + jnp.e + bias)
    if fn == "griewank":
        s = jnp.where(valid, x * x, 0.0).sum(axis=1) / 4000.0
        i = jnp.sqrt((lane + 1).astype(jnp.float32))
        p = jnp.where(valid, jnp.cos(x / i), 1.0).prod(axis=1)
        return s - p + 1.0 + bias
    if fn == "schwefel":
        t = jnp.where(valid, x * jnp.sin(jnp.sqrt(jnp.abs(x))), 0.0)
        return 418.9829 * dim - t.sum(axis=1) + bias
    if fn == "levy":
        w = 1.0 + (x - 1.0) / 4.0
        first = lane == 0
        mid = lane < (dim - 1)
        last = lane == (dim - 1)
        t1 = jnp.where(first, jnp.sin(jnp.pi * w) ** 2, 0.0).sum(axis=1)
        t2 = jnp.where(
            mid,
            (w - 1.0) ** 2 * (1.0 + 10.0 * jnp.sin(jnp.pi * w + 1.0) ** 2),
            0.0,
        ).sum(axis=1)
        t3 = jnp.where(
            last, (w - 1.0) ** 2 * (1.0 + jnp.sin(2.0 * jnp.pi * w) ** 2), 0.0
        ).sum(axis=1)
        return t1 + t2 + t3 + bias
    if fn == "dropwave":
        s = jnp.where(valid, x * x, 0.0).sum(axis=1)
        return -(1.0 + jnp.cos(12.0 * jnp.sqrt(s))) / (0.5 * s + 2.0) + bias
    if fn == "michalewicz":
        i = (lane + 1).astype(jnp.float32)
        t = jnp.sin(x) * jnp.sin(i * x * x / jnp.pi) ** 20
        return -jnp.where(valid, t, 0.0).sum(axis=1) + bias
    raise ValueError(fn)


def _kernel(x_ref, shift_ref, o_ref, *, fn: str, dim: int, bias: float):
    x = x_ref[...].astype(jnp.float32) - shift_ref[...].astype(jnp.float32)
    o_ref[...] = _eval_tile(x, fn, dim, bias).astype(o_ref.dtype)


def bench_eval(pop: jax.Array, fn: str, shift: jax.Array | None = None,
               bias: float = 0.0, pop_block: int = 8, *,
               interpret: bool = False) -> jax.Array:
    """pop: (P, D) f32 -> fitness (P,). ``shift``: (D,) offset (CEC'2008)."""
    if fn not in EVAL_TAGS:
        raise ValueError(
            f"no kernel body for eval tag {fn!r}; implemented: {EVAL_TAGS} "
            f"(kernels.registry maps function names to these tags)")
    P, D = pop.shape
    Dp = (D + 127) // 128 * 128
    Pp = (P + pop_block - 1) // pop_block * pop_block
    x = jnp.pad(pop, ((0, Pp - P), (0, Dp - D)))
    s = jnp.zeros((Dp,), pop.dtype) if shift is None else jnp.pad(shift, (0, Dp - D))
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias)
    out = pl.pallas_call(
        kernel,
        grid=(Pp // pop_block,),
        in_specs=[
            pl.BlockSpec((pop_block, Dp), lambda i: (i, 0)),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((pop_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(x, s[None, :])
    return out[:P]
