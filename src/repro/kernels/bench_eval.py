"""Fused population evaluation — Pallas TPU kernel.

The paper's hot loop: every meta-heuristic spends its 1M-evaluation budget in
``f(pop)`` (Fig. 4 protocol). This kernel evaluates a (pop_block, dim) tile per
grid step entirely in VMEM — one HBM read of the population, no intermediate
arrays — for the §V testbed functions listed in ``kernels.registry`` (sphere /
rastrigin / rosenbrock / ackley / griewank / schwefel / levy / dropwave /
michalewicz, incl. the CEC'2008 shifted Rosenbrock via a shift operand).

dim is carried whole per tile (the paper's 1000-D padded to 1024 lane-aligned).
Tile shapes are no longer hard-coded: ``kernels.autotune`` picks
``(pop_block, dim_pad)`` per shape-class from the roofline model (explicit
``pop_block=``/``KernelConfig`` fields still win). Rows added by the
``pop_block`` round-up are masked to **+inf fitness inside the kernel** — pad
rows can never win a downstream selection, rather than relying on the caller
slicing them off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig

# Objective bodies _eval_tile implements. ``kernels.registry`` maps function
# *names* to one of these tags (several names may share a tag); this tuple is
# the ground truth for what the kernel itself can evaluate.
EVAL_TAGS = (
    "sphere", "rastrigin", "rosenbrock", "ackley", "shifted_rosenbrock",
    "griewank", "schwefel", "levy", "dropwave", "michalewicz",
)


def _eval_tile(x: jax.Array, fn: str, dim: int, bias: float) -> jax.Array:
    """x: (P, Dp) f32 with zero padding beyond ``dim``; returns (P,)."""
    Dp = x.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = lane < dim
    if fn in ("rosenbrock", "shifted_rosenbrock"):
        if fn == "shifted_rosenbrock":
            x = jnp.where(valid, x + 1.0, 0.0)   # z = x - o + 1 (o applied outside)
        x0 = x
        x1 = jnp.concatenate([x[:, 1:], jnp.zeros_like(x[:, :1])], axis=1)
        pair = lane < (dim - 1)
        t = jnp.where(pair, 100.0 * (x1 - x0 * x0) ** 2 + (1.0 - x0) ** 2, 0.0)
        return t.sum(axis=1) + bias
    if fn == "sphere":
        return jnp.where(valid, x * x, 0.0).sum(axis=1) + bias
    if fn == "rastrigin":
        t = jnp.where(valid, x * x - 10.0 * jnp.cos(2.0 * jnp.pi * x) + 10.0, 0.0)
        return t.sum(axis=1) + bias
    if fn == "ackley":
        s1 = jnp.where(valid, x * x, 0.0).sum(axis=1) / dim
        s2 = jnp.where(valid, jnp.cos(2.0 * jnp.pi * x), 0.0).sum(axis=1) / dim
        return (-20.0 * jnp.exp(-0.2 * jnp.sqrt(s1)) - jnp.exp(s2)
                + 20.0 + jnp.e + bias)
    if fn == "griewank":
        s = jnp.where(valid, x * x, 0.0).sum(axis=1) / 4000.0
        i = jnp.sqrt((lane + 1).astype(jnp.float32))
        p = jnp.where(valid, jnp.cos(x / i), 1.0).prod(axis=1)
        return s - p + 1.0 + bias
    if fn == "schwefel":
        t = jnp.where(valid, x * jnp.sin(jnp.sqrt(jnp.abs(x))), 0.0)
        return 418.9829 * dim - t.sum(axis=1) + bias
    if fn == "levy":
        w = 1.0 + (x - 1.0) / 4.0
        first = lane == 0
        mid = lane < (dim - 1)
        last = lane == (dim - 1)
        t1 = jnp.where(first, jnp.sin(jnp.pi * w) ** 2, 0.0).sum(axis=1)
        t2 = jnp.where(
            mid,
            (w - 1.0) ** 2 * (1.0 + 10.0 * jnp.sin(jnp.pi * w + 1.0) ** 2),
            0.0,
        ).sum(axis=1)
        t3 = jnp.where(
            last, (w - 1.0) ** 2 * (1.0 + jnp.sin(2.0 * jnp.pi * w) ** 2), 0.0
        ).sum(axis=1)
        return t1 + t2 + t3 + bias
    if fn == "dropwave":
        s = jnp.where(valid, x * x, 0.0).sum(axis=1)
        return -(1.0 + jnp.cos(12.0 * jnp.sqrt(s))) / (0.5 * s + 2.0) + bias
    if fn == "michalewicz":
        i = (lane + 1).astype(jnp.float32)
        t = jnp.sin(x) * jnp.sin(i * x * x / jnp.pi) ** 20
        return -jnp.where(valid, t, 0.0).sum(axis=1) + bias
    raise ValueError(fn)


def _row_index(pop_block: int) -> jax.Array:
    """(pop_block,) absolute row index of this grid step (TPU needs >=2D iota)."""
    base = pl.program_id(0) * pop_block
    return base + jax.lax.broadcasted_iota(jnp.int32, (pop_block, 1), 0)[:, 0]


def _kernel(x_ref, shift_ref, o_ref, *, fn: str, dim: int, bias: float,
            n_rows: int):
    x = x_ref[...].astype(jnp.float32) - shift_ref[...].astype(jnp.float32)
    fit = _eval_tile(x, fn, dim, bias)
    # Pad rows from the pop_block round-up carry +inf fitness so they can
    # never be selected downstream (satellite: no clamp-overlap reliance).
    row_ok = _row_index(x.shape[0]) < n_rows
    o_ref[...] = jnp.where(row_ok, fit, jnp.inf).astype(o_ref.dtype)


def bench_eval(pop: jax.Array, fn: str, shift: jax.Array | None = None,
               bias: float = 0.0, pop_block: int | None = None, *,
               interpret: bool | None = None,
               kernel_cfg: KernelConfig | None = None) -> jax.Array:
    """pop: (P, D) f32 -> fitness (P,). ``shift``: (D,) offset (CEC'2008).

    Tiling comes from ``kernel_cfg`` (a :class:`KernelConfig`, typically
    threaded from ``ExecutorConfig.kernel``); unset fields are filled by the
    ``kernels.autotune`` roofline model for this shape-class. Explicit
    ``pop_block``/``interpret`` keywords override the config.
    """
    if fn not in EVAL_TAGS:
        raise ValueError(
            f"no kernel body for eval tag {fn!r}; implemented: {EVAL_TAGS} "
            f"(kernels.registry maps function names to these tags)")
    P, D = pop.shape
    cfg = autotune.resolve(
        autotune.merge(kernel_cfg, pop_block=pop_block, interpret=interpret),
        "bench_eval", P, D, tag=fn)
    dt = jnp.dtype(cfg.dtype)
    Dp = max(cfg.dim_pad, (D + 127) // 128 * 128)
    Pp = (P + cfg.pop_block - 1) // cfg.pop_block * cfg.pop_block
    x = jnp.pad(pop, ((0, Pp - P), (0, Dp - D))).astype(dt)
    s = jnp.zeros((Dp,), dt) if shift is None else \
        jnp.pad(shift, (0, Dp - D)).astype(dt)
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias, n_rows=P)
    out = pl.pallas_call(
        kernel,
        grid=(Pp // cfg.pop_block,),
        in_specs=[
            pl.BlockSpec((cfg.pop_block, Dp), lambda i: (i, 0)),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cfg.pop_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=cfg.interpret,
    )(x, s[None, :])
    return out[:P]
