"""Fused evaluate-and-select — the generic Pallas survivor kernel.

Every population meta-heuristic in the repo ends its generation the same way:
evaluate a candidate tile, compare against the incumbent, keep the winner.
This kernel fuses that tail — shifted objective evaluation (the shared
``bench_eval._eval_tile`` bodies) + thresholded acceptance — into one VMEM
pass, parameterized so a single entry covers both acceptance rules in use:

  greedy (DE/GA semantics)   accept iff  f(y) - f(x) <= 0      (thresh = 0)
  Metropolis (SA)            accept iff  u < exp(-dF / T)
                             ⟺  dF <= 0  or  dF < -T·ln(u)     (thresh row)

so the caller turns its Metropolis draw into a per-row threshold and the
kernel stays branch-free. Because acceptance is the *whole* state update for
SA chains and the portfolio's unified-policy branches dispatch through
``step_override`` (one traced call per branch), routing a branch through this
entry removes the per-op XLA dispatch the heterogeneous islands of PR 5 paid
inside ``lax.switch``.

Tile shapes resolve via ``kernels.autotune``; pad rows from the pop_block
round-up never accept and surface +inf fitness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig
from repro.kernels.bench_eval import EVAL_TAGS, _eval_tile, _row_index


def _kernel(pop_ref, fit_ref, trial_ref, th_ref, shift_ref,
            npop_ref, nfit_ref, acc_ref, *, fn: str, dim: int, bias: float,
            n_rows: int):
    pop = pop_ref[...].astype(jnp.float32)
    fit = fit_ref[...].astype(jnp.float32)             # (P, 1)
    trial = trial_ref[...].astype(jnp.float32)
    th = th_ref[...].astype(jnp.float32)               # (P, 1)
    shift = shift_ref[...].astype(jnp.float32)         # (1, Dp)

    tfit = _eval_tile(trial - shift, fn, dim, bias)
    dF = tfit - fit[:, 0]
    row_ok = _row_index(pop.shape[0]) < n_rows
    acc = ((dF <= 0.0) | (dF < th[:, 0])) & row_ok
    nfit = jnp.where(acc, tfit, fit[:, 0])
    npop_ref[...] = jnp.where(acc[:, None], trial, pop).astype(npop_ref.dtype)
    nfit_ref[...] = jnp.where(row_ok, nfit, jnp.inf)[:, None].astype(
        nfit_ref.dtype)
    acc_ref[...] = acc[:, None].astype(acc_ref.dtype)


def eval_select(pop: jax.Array, fit: jax.Array, trial: jax.Array,
                thresh: jax.Array | None = None, fn: str = "sphere",
                shift: jax.Array | None = None, bias: float = 0.0,
                pop_block: int | None = None, *,
                interpret: bool | None = None,
                kernel_cfg: KernelConfig | None = None):
    """Fused evaluate + accept over candidate rows.

    pop, trial: (P, D) incumbents and candidates; fit: (P,) incumbent
    fitness; thresh: (P,) per-row acceptance slack (``None``/0 = greedy,
    ``-T*ln(u)`` = Metropolis at temperature T). Returns
    (new_pop, new_fit, accepted).
    """
    assert fn in EVAL_TAGS, fn
    P, D = pop.shape
    cfg = autotune.resolve(
        autotune.merge(kernel_cfg, pop_block=pop_block, interpret=interpret),
        "eval_select", P, D, tag=fn)
    dt = jnp.dtype(cfg.dtype)
    Dp = max(cfg.dim_pad, (D + 127) // 128 * 128)
    Pp = (P + cfg.pop_block - 1) // cfg.pop_block * cfg.pop_block
    padPD = lambda a: jnp.pad(a, ((0, Pp - P), (0, Dp - D))).astype(dt)
    padP = lambda a: jnp.pad(a, (0, Pp - P))[:, None]
    th = jnp.zeros((P,), jnp.float32) if thresh is None else thresh
    s = (jnp.zeros((1, Dp), dt) if shift is None
         else jnp.pad(shift, (0, Dp - D)).astype(dt)[None, :])
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias, n_rows=P)
    row = lambda i: (i, 0)
    vec = pl.BlockSpec((cfg.pop_block, Dp), row)
    col = pl.BlockSpec((cfg.pop_block, 1), row)
    npop, nfit, acc = pl.pallas_call(
        kernel,
        grid=(Pp // cfg.pop_block,),
        in_specs=[vec, col, vec, col, pl.BlockSpec((1, Dp), lambda i: (0, 0))],
        out_specs=[vec, col, col],
        out_shape=[jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32)],
        interpret=cfg.interpret,
    )(padPD(pop), padP(fit), padPD(trial), padP(th), s)
    return (npop[:P, :D].astype(pop.dtype), nfit[:P, 0], acc[:P, 0] > 0.5)
