"""Fused Differential-Evolution generation — Pallas TPU kernel.

One grid step processes a (pop_block, dim) tile and performs the paper's whole
DDE inner loop in VMEM: mutation (base + w*(b-c)), binomial crossover with the
guaranteed j_rand dimension, box clipping, objective evaluation (fused
bench_eval tile) and greedy selection — writing back only the surviving
vectors. The naive XLA pipeline materializes mutant + trial + fitness in HBM
(5 full population round-trips per generation); this kernel does 1 read of
{pop, bases} + 1 write.

Donor rows (pop[a], pop[b], pop[c]) are pre-gathered by the XLA caller —
random row gather is cheap relative to evaluation and keeps the kernel free of
cross-tile loads. Tile shapes come from ``kernels.autotune`` (roofline-scored
per shape-class) unless pinned by the caller; pad rows from the ``pop_block``
round-up are excluded from selection in-kernel and surface as +inf fitness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.autotune import KernelConfig
from repro.kernels.bench_eval import EVAL_TAGS, _eval_tile, _row_index


def _kernel(pop_ref, fit_ref, pa_ref, pb_ref, pc_ref, u_ref, jr_ref, shift_ref,
            npop_ref, nfit_ref, *, fn: str, dim: int, bias: float,
            w: float, px: float, lo: float, hi: float, n_rows: int):
    pop = pop_ref[...].astype(jnp.float32)
    fit = fit_ref[...].astype(jnp.float32)
    pa = pa_ref[...].astype(jnp.float32)
    pb = pb_ref[...].astype(jnp.float32)
    pc = pc_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    jr = jr_ref[...]                                   # (P, 1) int32
    shift = shift_ref[...].astype(jnp.float32)         # (1, Dp)

    lane = jax.lax.broadcasted_iota(jnp.int32, pop.shape, 1)
    valid = lane < dim
    mutant = jnp.clip(pa + w * (pb - pc), lo, hi)
    cross = (u < px) | (lane == jr)
    trial = jnp.where(cross & valid, mutant, pop)

    tfit = _eval_tile(trial - shift, fn, dim, bias)
    row_ok = _row_index(pop.shape[0]) < n_rows
    # Pad rows never win selection and carry +inf fitness on the way out.
    better = (tfit <= fit[:, 0]) & row_ok
    nfit = jnp.where(better, tfit, fit[:, 0])
    nfit = jnp.where(row_ok, nfit, jnp.inf)
    npop_ref[...] = jnp.where(better[:, None], trial, pop).astype(npop_ref.dtype)
    nfit_ref[...] = nfit[:, None].astype(nfit_ref.dtype)


def de_step(pop: jax.Array, fit: jax.Array, idx_abc: jax.Array, u: jax.Array,
            jrand: jax.Array, fn: str = "sphere",
            shift: jax.Array | None = None, bias: float = 0.0,
            w: float = 0.5, px: float = 0.2, lo: float = -100.0,
            hi: float = 100.0, pop_block: int | None = None, *,
            interpret: bool | None = None,
            kernel_cfg: KernelConfig | None = None):
    """One fused DE/rand/1/bin generation.

    pop (P, D) f32; fit (P,); idx_abc (3, P) i32 donor indices; u (P, D)
    uniforms; jrand (P,) i32. Returns (new_pop, new_fit). Tiling resolves via
    ``kernel_cfg``/``kernels.autotune`` as in ``bench_eval``."""
    assert fn in EVAL_TAGS, fn  # fused_de gating happens at de.make (by name)
    P, D = pop.shape
    cfg = autotune.resolve(
        autotune.merge(kernel_cfg, pop_block=pop_block, interpret=interpret),
        "de_step", P, D, tag=fn)
    dt = jnp.dtype(cfg.dtype)
    Dp = max(cfg.dim_pad, (D + 127) // 128 * 128)
    Pp = (P + cfg.pop_block - 1) // cfg.pop_block * cfg.pop_block
    padPD = lambda a: jnp.pad(a, ((0, Pp - P), (0, Dp - D))).astype(dt)
    pa, pb, pc = pop[idx_abc[0]], pop[idx_abc[1]], pop[idx_abc[2]]
    s = (jnp.zeros((Dp,), dt) if shift is None
         else jnp.pad(shift, (0, Dp - D)).astype(dt))
    kernel = functools.partial(_kernel, fn=fn, dim=D, bias=bias, w=w, px=px,
                               lo=lo, hi=hi, n_rows=P)
    row = lambda i: (i, 0)
    new_pop, new_fit = pl.pallas_call(
        kernel,
        grid=(Pp // cfg.pop_block,),
        in_specs=[
            pl.BlockSpec((cfg.pop_block, Dp), row),
            pl.BlockSpec((cfg.pop_block, 1), row),
            pl.BlockSpec((cfg.pop_block, Dp), row),
            pl.BlockSpec((cfg.pop_block, Dp), row),
            pl.BlockSpec((cfg.pop_block, Dp), row),
            pl.BlockSpec((cfg.pop_block, Dp), row),
            pl.BlockSpec((cfg.pop_block, 1), row),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((cfg.pop_block, Dp), row),
                   pl.BlockSpec((cfg.pop_block, 1), row)],
        out_shape=[jax.ShapeDtypeStruct((Pp, Dp), dt),
                   jax.ShapeDtypeStruct((Pp, 1), jnp.float32)],
        interpret=cfg.interpret,
    )(padPD(pop), jnp.pad(fit, (0, Pp - P))[:, None], padPD(pa), padPD(pb),
      padPD(pc), padPD(u), jnp.pad(jrand, (0, Pp - P))[:, None], s[None, :])
    return new_pop[:P, :D].astype(pop.dtype), new_fit[:P, 0]
