from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import bench_eval, de_step, flash_attention, ssd_scan  # noqa: F401
