from repro.kernels import ops, ref, registry  # noqa: F401
from repro.kernels.ops import bench_eval, de_step, flash_attention, ssd_scan  # noqa: F401
from repro.kernels.registry import KernelSpec, get_spec  # noqa: F401
