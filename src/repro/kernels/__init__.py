from repro.kernels import autotune, ops, ref, registry  # noqa: F401
from repro.kernels.autotune import KernelConfig  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    bench_eval, de_step, eval_select, flash_attention, ga_step, pso_step,
    ssd_scan,
)
from repro.kernels.registry import KernelSpec, get_spec  # noqa: F401
