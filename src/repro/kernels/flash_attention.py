"""Flash attention — Pallas TPU kernel.

Streaming-softmax attention tiled for VMEM/MXU: grid (batch*heads, q_blocks,
kv_blocks); the kv dimension is the minor (sequential) grid axis on TPU, so the
running max / sum / output accumulator live in VMEM scratch across kv steps and
are flushed at the last kv block. Supports causal masking, sliding windows
(gemma2 local layers) and score softcap.

Block sizes default to q=256, kv=512 (MXU-aligned multiples of 128; ~
(256+512)*head_dim*2B + 256*512*4B ≈ 0.8 MB VMEM live per step at head_dim=128,
well inside the ~16 MB/core budget with double buffering).

Validated against ref.flash_attention_ref with interpret=True (CPU container);
TPU is the deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            q_block: int, kv_block: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                  # (kvb, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < kv_len                               # kv padding
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    q_block: int = 256, kv_block: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, hd); k, v: (BH, T, hd) — KV heads pre-expanded. Returns (BH, S, hd).

    S and T are padded to the block sizes internally; pad keys are masked."""
    BH, S, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    q_block = min(q_block, max(128, S))
    kv_block = min(kv_block, max(128, T))
    Sp = (S + q_block - 1) // q_block * q_block
    Tp = (T + kv_block - 1) // kv_block * kv_block
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0)))

    grid = (BH, Sp // q_block, Tp // kv_block)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        q_block=q_block, kv_block=kv_block, kv_len=T)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
