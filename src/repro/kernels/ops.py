"""jit'd public wrappers for the Pallas kernels.

``use_pallas`` selects the kernel; on this CPU container the kernels run in
interpret mode (the TPU Mosaic compiler is unavailable), so the wrappers
default to interpret=True off-TPU and compiled Pallas on TPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import bench_eval as _be
from repro.kernels import de_step as _de
from repro.kernels import eval_select as _es
from repro.kernels import flash_attention as _fa
from repro.kernels import ga_step as _ga
from repro.kernels import pso_step as _ps
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "use_pallas"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    use_pallas=True):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_scan(xh, dt, A, Bm, Cm, chunk=128, use_pallas=True):
    if not use_pallas:
        return ref.ssd_ref(xh, dt, A, Bm, Cm)
    return _ssd.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk,
                         interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("fn", "bias", "use_pallas"))
def bench_eval(pop, fn, shift=None, bias=0.0, use_pallas=True):
    if not use_pallas:
        return ref.bench_eval_ref(pop, fn, shift, bias)
    return _be.bench_eval(pop, fn, shift=shift, bias=bias,
                          interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("fn", "bias", "w", "px", "lo", "hi",
                                   "use_pallas"))
def de_step(pop, fit, idx_abc, u, jrand, fn="sphere", shift=None, bias=0.0,
            w=0.5, px=0.2, lo=-100.0, hi=100.0, use_pallas=True):
    if not use_pallas:
        return ref.de_step_ref(pop, fit, idx_abc, u, jrand, fn, shift, bias,
                               w, px, lo, hi)
    return _de.de_step(pop, fit, idx_abc, u, jrand, fn=fn, shift=shift,
                       bias=bias, w=w, px=px, lo=lo, hi=hi,
                       interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("fn", "bias", "w", "fp", "fg", "vmax",
                                   "lo", "hi", "use_pallas"))
def pso_step(x, v, pbest, pbest_f, r1, r2, gbest, fn="sphere", shift=None,
             bias=0.0, w=0.6, fp=1.0, fg=1.0, vmax=float("inf"), lo=-100.0,
             hi=100.0, use_pallas=True):
    if not use_pallas:
        return ref.pso_step_ref(x, v, pbest, pbest_f, r1, r2, gbest, fn,
                                shift, bias, w, fp, fg, vmax, lo, hi)
    return _ps.pso_step(x, v, pbest, pbest_f, r1, r2, gbest, fn=fn,
                        shift=shift, bias=bias, w=w, fp=fp, fg=fg, vmax=vmax,
                        lo=lo, hi=hi, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("fn", "bias", "pc", "pm", "sigma_m", "lo",
                                   "hi", "use_pallas"))
def ga_step(p1, p2, slot_pop, slot_f, cut, co, um, noise, fn="sphere",
            shift=None, bias=0.0, pc=0.7, pm=0.1, sigma_m=1.0, lo=-100.0,
            hi=100.0, use_pallas=True):
    if not use_pallas:
        return ref.ga_step_ref(p1, p2, slot_pop, slot_f, cut, co, um, noise,
                               fn, shift, bias, pc, pm, sigma_m, lo, hi)
    return _ga.ga_step(p1, p2, slot_pop, slot_f, cut, co, um, noise, fn=fn,
                       shift=shift, bias=bias, pc=pc, pm=pm, sigma_m=sigma_m,
                       lo=lo, hi=hi, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("fn", "bias", "use_pallas"))
def eval_select(pop, fit, trial, thresh=None, fn="sphere", shift=None,
                bias=0.0, use_pallas=True):
    if not use_pallas:
        return ref.eval_select_ref(pop, fit, trial, thresh, fn, shift, bias)
    return _es.eval_select(pop, fit, trial, thresh, fn=fn, shift=shift,
                           bias=bias, interpret=not _on_tpu())
