"""Per-function kernel registry.

Maps benchmark-function names (keys of ``functions.benchmarks.FUNCTIONS`` plus
``shifted_rosenbrock``) to the fused-kernel specs that can evaluate them.  This
replaces the old ad-hoc ``SUPPORTED`` tuple in ``bench_eval.py``: the executor's
``pallas`` backend and the fused DE step both consult this table, so adding a
kernel implementation for a new testbed function is one ``register()`` call.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """How the Pallas layer evaluates one benchmark function.

    ``eval_tag`` is the branch selector inside ``bench_eval._eval_tile``; it is
    usually the function name itself but kept separate so several registered
    names can share one kernel body (e.g. shifted variants).  ``fused_de``
    marks the objective as usable inside the fused whole-generation kernels
    (``de_step``/``pso_step``/``ga_step``/``eval_select`` — the name predates
    the non-DE kernels; they all reuse ``_eval_tile``, so one flag gates the
    lot and every current tag qualifies).
    """

    name: str
    eval_tag: str
    fused_de: bool = True


_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    """Add (or replace) a function's kernel spec; returns it for chaining."""
    _REGISTRY[spec.name] = spec
    return spec


def supported(name: str) -> bool:
    """True when a Pallas kernel is registered for function ``name``."""
    return name in _REGISTRY


def registered() -> tuple[str, ...]:
    """Names with a kernel implementation, in registration order."""
    return tuple(_REGISTRY)


def get_spec(name: str) -> KernelSpec:
    """Kernel spec for ``name``; KeyError (with guidance) if unregistered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no Pallas kernel registered for function {name!r}; "
            f"registered: {sorted(_REGISTRY)} "
            f"(use ExecutorConfig(backend='xla') for unregistered functions)"
        ) from None


# The §V.B testbed coverage.  weierstrass is deliberately absent: its b^k
# arguments (3^20 ~ 3.5e9) exceed f32 argument-reduction precision, so a
# reordered kernel summation cannot hold a meaningful parity bound.
for _name in (
    "sphere",
    "rastrigin",
    "rosenbrock",
    "ackley",
    "shifted_rosenbrock",
    "griewank",
    "schwefel",
    "levy",
    "dropwave",
    "michalewicz",
):
    register(KernelSpec(name=_name, eval_tag=_name))
