from repro.data.pipeline import DataConfig, SyntheticStream, shard_batch  # noqa: F401
