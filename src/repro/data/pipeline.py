"""Deterministic synthetic token pipeline with a restorable cursor.

Production shape: an infinite, seeded stream of (tokens, labels) batches with
modality stubs for the VLM/audio archs. The cursor (step index) is part of the
checkpoint, so restart resumes the exact stream position on any mesh — batches
are generated per *global* index and sharded on device_put, making the stream
independent of the data-parallel size (elastic restarts see identical data).

Synthetic distribution: a tiny deterministic Markov-ish mixture (not uniform)
so training losses actually decrease and overfitting bugs are visible.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 17
    n_species: int = 32          # mixture components


class SyntheticStream:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig = DataConfig(),
                 start_step: int = 0):
        self.cfg = cfg
        self.dcfg = dcfg
        self.step = start_step

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])
        assert int(st["seed"]) == self.dcfg.seed, "data seed changed across restart"

    def _batch_np(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
        rng = np.random.default_rng(self.dcfg.seed * 1_000_003 + step)
        # per-sequence species with its own ngram bias -> learnable structure
        species = rng.integers(0, self.dcfg.n_species, size=(B, 1))
        base = rng.integers(0, V, size=(B, S), dtype=np.int64)
        drift = (np.arange(S)[None, :] * (species + 1)) % V
        tokens = (base // 4 + drift) % V
        out: dict[str, np.ndarray] = {}
        if cfg.frontend == "audio_stub":
            emb_rng = np.random.default_rng(step + 7)
            out["embeds"] = emb_rng.standard_normal(
                (B, S, cfg.frontend_dim), dtype=np.float32)
            out["labels"] = np.concatenate(
                [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        elif cfg.frontend == "vlm_stub":
            emb_rng = np.random.default_rng(step + 7)
            out["embeds"] = emb_rng.standard_normal(
                (B, cfg.frontend_len, cfg.frontend_dim), dtype=np.float32)
            out["tokens"] = tokens[:, :S - cfg.frontend_len].astype(np.int32)
            labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
            labels[:, :cfg.frontend_len] = -100       # image prefix unsupervised
            out["labels"] = labels.astype(np.int32)
        else:
            out["tokens"] = tokens.astype(np.int32)
            out["labels"] = np.concatenate(
                [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        b = self._batch_np(self.step)
        self.step += 1
        return b


def shard_batch(batch: dict[str, np.ndarray], shardings: dict) -> dict:
    """Host -> device with the step's input shardings (double-buffer friendly)."""
    return {k: jax.device_put(v, shardings[k]) if k in shardings
            else jnp.asarray(v) for k, v in batch.items()}
