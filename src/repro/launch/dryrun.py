import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# --- everything below may import jax -------------------------------------
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config             # noqa: E402
from repro.configs.shapes import SHAPES, cell_supported, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.steps import (                        # noqa: E402
    make_decode_step, make_prefill_step, make_train_step)
from repro.models.transformer import init_params        # noqa: E402
from repro.optim import adam                            # noqa: E402
from repro.parallel import roofline as rl               # noqa: E402
from repro.parallel.memmodel import analytic_memory     # noqa: E402
from repro.parallel.sharding import (                   # noqa: E402
    batch_specs, compute_specs, decode_state_specs, opt_state_specs,
    param_specs, to_shardings)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves:
  * the sharding config is coherent (no partitioner errors),
  * the program fits per-device HBM (memory_analysis),
  * and yields the roofline terms (cost_analysis + collective parse).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod ...
"""

SDS = jax.ShapeDtypeStruct


def _parse_overrides(sets: list[str] | None) -> dict:
    out = {}
    for kv in sets or []:
        k, v = kv.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool,
               save_hlo: str | None = None,
               overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    sp = SHAPES[shape]
    cfg = dataclasses.replace(cfg, seq_len=sp.seq_len,
                              global_batch=sp.global_batch,
                              **(overrides or {}))

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    kind, specs = input_specs(cfg, shape)

    params_shape = jax.eval_shape(lambda k: init_params(k, cfg),
                                  SDS((2,), jnp.uint32))
    c_spec = compute_specs(cfg, axes)        # None for pure-tp archs
    c_sh = to_shardings(mesh, c_spec) if c_spec is not None else None
    p_sh = to_shardings(mesh, param_specs(cfg, axes))
    b_spec, bax = batch_specs(cfg, axes, sp.global_batch)
    b_sh = to_shardings(mesh, b_spec)
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    from repro.parallel import ctx as _ctx

    moe_rules = {}
    dax = ("pod", "data") if multi_pod else "data"
    if cfg.num_experts and cfg.sharding_mode != "dp+zero1":
        ep = "model" if cfg.num_experts % 16 == 0 else None
        f_ax = None if ep else "model"
        moe_rules = {
            "moe_eb": NamedSharding(mesh, P(dax, ep, None, None)),
            "moe_hidden": NamedSharding(mesh, P(dax, ep, None, f_ax)),
        }
    if (cfg.n_heads % 16 and cfg.sharding_mode != "dp+zero1"
            and kind != "decode"):
        # heads don't divide the model axis: sequence-parallel attention
        bx = dax if sp.global_batch % (32 if multi_pod else 16) == 0 else None
        moe_rules["attn_seq_q"] = NamedSharding(mesh, P(bx, "model", None, None))
        moe_rules["attn_seq_kv"] = NamedSharding(mesh, P(bx, "model", None, None))

    def _lower(jitted, *a):
        with _ctx.sharding_rules(**moe_rules):
            return jitted.lower(*a)

    t0 = time.time()
    if kind == "train":
        opt_shape = jax.eval_shape(adam.init, params_shape)
        o_sh = to_shardings(mesh, opt_state_specs(cfg, axes))
        lbl_sh = to_shardings(mesh, {"labels": P(bax, None)})
        batch_sh = {**b_sh, "labels": lbl_sh["labels"]}
        step = make_train_step(cfg, compute_shardings=c_sh)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, batch_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        lowered = _lower(jitted, params_shape, opt_shape, specs["batch"])
    elif kind == "prefill":
        # serving holds params in the TP compute layout (no FSDP storage)
        serve_p_sh = c_sh if c_sh is not None else p_sh
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(serve_p_sh, b_sh))
        lowered = _lower(jitted, params_shape, specs["batch"])
    else:  # decode
        serve_p_sh = c_sh if c_sh is not None else p_sh
        s_sh = to_shardings(mesh, decode_state_specs(cfg, axes, sp.global_batch))
        # decode batches differ from train batches (single token / frame)
        db_sh = to_shardings(mesh, jax.tree.map(
            lambda x: P(bax, *([None] * (x.ndim - 1))), specs["batch"]))
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(serve_p_sh, s_sh, db_sh),
                         out_shardings=(None, s_sh), donate_argnums=(1,))
        lowered = _lower(jitted, params_shape, specs["state"], specs["batch"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    roof = rl.analyze(compiled, hlo)
    mem = compiled.memory_analysis()
    n_chips = 512 if multi_pod else 256
    analytic = analytic_memory(
        cfg, kind, axes, sp.global_batch, sp.seq_len, params_shape,
        param_specs(cfg, axes), c_spec,
        state_shape=specs.get("state"),
        state_specs=(decode_state_specs(cfg, axes, sp.global_batch)
                     if kind == "decode" else None))
    return {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "status": "ok",
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "per_device": roof.to_dict(),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # CPU-backend measurement: an UPPER BOUND — XLA:CPU legalizes all
            # bf16 arithmetic to f32 (no native bf16), duplicating bf16
            # buffers at f32 width. See EXPERIMENTS.md §Dry-run.
            "peak_bytes_cpu_backend": roof.peak_bytes,
            "analytic_tpu_bytes": analytic,
            "fits_16GB_analytic": bool(analytic["total"] < 16e9),
        },
        "n_chips": n_chips,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--set", action="append", default=None, metavar="K=V",
                    help="config overrides for hillclimbing, e.g. "
                         "--set sharding_mode=dp+zero1 --set ssm_chunk=128")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args()
    overrides = _parse_overrides(getattr(args, "set"))

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, why = cell_supported(cfg, shape)
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if not ok:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "skip", "reason": why}
                else:
                    try:
                        rec = lower_cell(arch, shape, mp, save_hlo=args.save_hlo,
                                         overrides=overrides)
                    except Exception as e:  # a failure here is a bug in the system
                        rec = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
                line = f"{tag:60s} {rec['status']}"
                if rec["status"] == "ok":
                    r = rec["per_device"]
                    line += (f"  peak={rec['memory']['peak_bytes_cpu_backend']/2**30:6.2f}GiB"
                             f"  tpu~{rec['memory']['analytic_tpu_bytes']['total']/2**30:6.2f}GiB"
                             f"  tc={r['t_compute']*1e3:8.3f}ms"
                             f"  tm={r['t_memory']*1e3:8.3f}ms"
                             f"  tx={r['t_collective']*1e3:8.3f}ms"
                             f"  bottleneck={r['bottleneck']}"
                             f"  (compile {rec['t_compile_s']}s)")
                elif rec["status"] == "FAIL":
                    line += "  " + rec["error"][:140]
                print(line, flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run: {n_ok} ok / {n_skip} skip / {n_fail} FAIL ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
