"""Step functions: train / prefill / decode, assembled for jit+shard.

These are the units the dry-run lowers and the launcher runs: pure functions of
(params, [opt_state | cache], batch) with donation-friendly signatures.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, loss_fn, prefill
from repro.optim import adam

PyTree = Any


def _cast_params(params: PyTree, cfg: ModelConfig,
                 compute_shardings: PyTree | None = None) -> PyTree:
    """One sharded cast master->compute dtype before the layer loop; with
    ``compute_shardings`` (tp+fsdp archs) the bf16 copies are additionally
    constrained to the TP compute layout — the single per-step ZeRO weight
    all-gather, whose autodiff transpose is the grad reduce-scatter."""
    cd = jnp.dtype(cfg.compute_dtype)
    cast = jax.tree.map(
        lambda p: p.astype(cd) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)
    if compute_shardings is None:
        return cast
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s) if s is not None else x,
        cast, compute_shardings)


def make_train_step(cfg: ModelConfig, adam_cfg: adam.AdamConfig | None = None,
                    compute_shardings: PyTree | None = None):
    acfg = adam_cfg or adam.AdamConfig()

    def train_step(params: PyTree, opt_state: adam.AdamState, batch: PyTree):
        def lf(p):
            return loss_fn(_cast_params(p, cfg, compute_shardings), cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_opt = adam.update(grads, opt_state, params, acfg)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {**metrics, "loss": loss, "grad_norm": gn}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    # serving uses the TP compute layout directly (no FSDP storage to gather)
    def prefill_step(params: PyTree, batch: PyTree):
        return prefill(_cast_params(params, cfg), cfg,
                       tokens=batch.get("tokens"), embeds=batch.get("embeds"))

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params: PyTree, state: PyTree, batch: PyTree):
        return decode_step(_cast_params(params, cfg), cfg, state,
                           tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"))

    return serve_step


def make_prefill_decode(cfg: ModelConfig):
    """Cache-filling prefill in ONE dispatch: the whole (B, S) prompt is
    teacher-forced through the decode cache and the last-position logits come
    back ready for sampling. Attention archs run all S positions in parallel
    (multi-token ``decode_step``); recurrent archs scan the prompt inside the
    same jit — either way the host issues one call, not O(S)."""

    def prefill_decode(params: PyTree, state: PyTree, batch: PyTree):
        p = _cast_params(params, cfg)
        if cfg.block_pattern == "attn":
            return decode_step(p, cfg, state, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"))

        toks, embs = batch.get("tokens"), batch.get("embeds")
        xs = toks if embs is None else embs

        # carry the latest logits instead of stacking all S of them — only
        # the last position feeds sampling, so an (S, B, Vp) scan output
        # would be pure wasted HBM at long prompts
        def body(carry: tuple, x_t):
            st, _ = carry
            logits, st = decode_step(
                p, cfg, st,
                tokens=x_t[:, None] if embs is None else None,
                embeds=x_t[:, None] if embs is not None else None)
            return (st, logits), None

        logits0 = jnp.zeros((xs.shape[0], cfg.padded_vocab), jnp.float32)
        (state, logits), _ = jax.lax.scan(body, (state, logits0),
                                          jnp.swapaxes(xs, 0, 1))
        return logits, state

    return prefill_decode
