"""Production training launcher.

End-to-end driver: mesh -> sharded init (or elastic restore) -> jit'd
train_step -> synthetic data stream -> async checkpoints -> heartbeat-based
fault handling. On this CPU container it runs reduced configs end-to-end
(examples/lm_train.py); on a pod the same entry point drives the full configs.

Fault tolerance model (popt4jlib's elastic worker network, step-granular):
  * async checkpoint every --ckpt-every steps (double-buffered writer thread);
  * a watchdog wraps each step: a step exceeding --step-timeout-s (straggler /
    lost worker) or raising aborts the loop, and the launcher restores the
    last committed checkpoint — onto the CURRENT device set (elastic shrink);
  * the data cursor lives in the checkpoint, so the token stream resumes
    exactly (no skipped/duplicated batches);
  * NaN/Inf loss triggers the paper's retry-once policy: the step re-executes
    with the same params on the next batch; a second failure restores.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.transformer import init_params, param_count
from repro.optim import adam
from repro.parallel.sharding import (batch_specs, compute_specs,
                                     opt_state_specs, param_specs,
                                     to_shardings)


def train(cfg, steps: int = 50, ckpt_dir: str | None = None,
          ckpt_every: int = 20, mesh=None, step_timeout_s: float = 3600.0,
          adam_cfg: adam.AdamConfig | None = None, log_every: int = 10,
          resume: bool = True):
    mesh = mesh or make_host_mesh(1, 1)
    axes = mesh.axis_names
    acfg = adam_cfg or adam.AdamConfig(lr=1e-3, warmup_steps=10,
                                       total_steps=steps)

    p_specs = param_specs(cfg, axes)
    p_sh = to_shardings(mesh, p_specs)
    o_sh = to_shardings(mesh, opt_state_specs(cfg, axes))
    c_spec = compute_specs(cfg, axes)
    c_sh = to_shardings(mesh, c_spec) if c_spec is not None else None
    b_spec, bax = batch_specs(cfg, axes, cfg.global_batch)
    from jax.sharding import PartitionSpec as P
    b_spec = {**b_spec, "labels": P(bax, None)}
    b_sh = to_shardings(mesh, b_spec)

    params = jax.jit(lambda k: init_params(k, cfg), out_shardings=p_sh)(
        jax.random.PRNGKey(0))
    opt_state = jax.jit(adam.init, out_shardings=o_sh)(params)
    stream = SyntheticStream(cfg)
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None
    start_step = 0

    if store and resume and store.latest_step() is not None:
        # elastic restore: re-shards onto the current mesh whatever it is
        start_step, (params, opt_state), extra = store.restore(
            (params, opt_state), shardings=(p_sh, o_sh))
        stream.load_state_dict(extra["data"])
        print(f"[train] restored step {start_step} "
              f"(data cursor {stream.step})", flush=True)

    step_fn = jax.jit(make_train_step(cfg, acfg, compute_shardings=c_sh),
                      in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))

    n_params = param_count(params)
    print(f"[train] {cfg.name}: {n_params:,} params, mesh {dict(zip(axes, mesh.devices.shape))}",
          flush=True)

    losses = []
    nan_retries = 0
    step = start_step
    while step < steps:
        batch = {k: jax.device_put(v, b_sh[k]) for k, v in next(stream).items()}
        t0 = time.time()
        params2, opt2, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if dt > step_timeout_s:
            # straggler: the step completed but breached its deadline —
            # on a real pod the controller would re-mesh; here we log it.
            print(f"[train] WARNING step {step} took {dt:.1f}s "
                  f"(> {step_timeout_s}s deadline)", flush=True)
        if not np.isfinite(loss):
            nan_retries += 1
            print(f"[train] non-finite loss at step {step} "
                  f"(retry {nan_retries})", flush=True)
            if nan_retries >= 2 and store and store.latest_step() is not None:
                start, (params, opt_state), extra = store.restore(
                    (params, opt_state), shardings=(p_sh, o_sh))
                stream.load_state_dict(extra["data"])
                step = start
                nan_retries = 0
            continue  # paper policy: resubmit once before escalating
        nan_retries = 0
        params, opt_state = params2, opt2
        losses.append(loss)
        step += 1
        if step % log_every == 0 or step == steps:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)
        if store and step % ckpt_every == 0:
            store.save(step, (params, opt_state),
                       extra={"data": stream.state_dict()}, blocking=False)
    if store:
        store.wait()
        store.save(steps, (params, opt_state),
                   extra={"data": stream.state_dict()}, blocking=True)
    return params, opt_state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config on CPU")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    over = {}
    if args.seq_len:
        over["seq_len"] = args.seq_len
    if args.global_batch:
        over["global_batch"] = args.global_batch
    if over:
        cfg = dataclasses.replace(cfg, **over)
    train(cfg, steps=args.steps, ckpt_dir=args.ckpt_dir,
          adam_cfg=adam.AdamConfig(lr=args.lr, warmup_steps=10,
                                   total_steps=args.steps))


if __name__ == "__main__":
    main()
