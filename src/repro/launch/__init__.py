from repro.launch import mesh, steps  # noqa: F401
