"""Cross-host federation coordinator — the paper's §IV pdbtexec network of
cooperating JVMs, rebuilt over ``opt_serve`` workers (DESIGN.md §13).

popt4jlib scales past one machine by running optimizer processes on separate
hosts that exchange candidate solutions by message passing. The reproduction's
analogue keeps each host exactly what it already is — an ``opt_serve`` JSONL
worker with its own scheduler, devices and checkpoint store — and adds this
thin coordinator, which:

* spawns (or connects to) N workers, each a ``repro.launch.opt_serve``
  process serving TCP-JSONL, with per-worker checkpoint directories and
  optionally heterogeneous backends (``WorkerSpec.backend``) and per-worker
  algorithms — the Java network's mixed-solver deployments;
* runs the optimization as ``legs``: every leg submits one fixed-seed job per
  worker (seeds derived deterministically from ``seed``/leg/worker), blocks
  on the results, then routes each worker's best candidate **ring-wise** to
  its successor as the next leg's ``OptRequest.warm`` immigrants — the
  cross-host migration hop, at leg granularity;
* tolerates worker death/rejoin through the PR 7 checkpoint manifests: a
  worker that dies mid-leg (SIGKILL included) is respawned with
  ``--resume-dir`` pointing at its own checkpoint store, which restores the
  interrupted bucket under its **original job ids** and finishes it
  bit-identically; jobs the checkpoints never captured (killed pre-snapshot,
  or finished-and-evicted) are resubmitted under the same id with the same
  request, which recomputes the identical fixed-seed answer.

Because every job seed and every warm-routing decision is a pure function of
``FederationConfig``, the federation's final incumbent is deterministic: a
run that loses a worker mid-leg finishes with the same best value as an
uninterrupted run (``tests/test_federation.py`` SIGKILLs a worker to prove
it).

Walkthrough (coordinator + 2 local workers, kill/resume demo) in
``docs/DISTRIBUTED.md``::

    PYTHONPATH=src python -m repro.launch.federate \
        --n-workers 2 --legs 3 --fn rastrigin --dim 8 \
        --evals-per-leg 4000 --checkpoint-root /tmp/fed --demo-kill 1:1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import signal
import socket
import subprocess
import sys
import time
from typing import Any, IO

_LISTEN_RE = re.compile(r"listening on ([\w\.\-]+):(\d+)")


class WorkerDied(RuntimeError):
    """A worker's socket failed mid-conversation (crash, SIGKILL, network)."""


class JsonlClient:
    """One JSONL-over-TCP conversation with an ``opt_serve`` worker.

    Newline-framed request/reply in lockstep, mirroring the Java
    ``PDBTExecSingleCltWrkInitSrv`` client. Any socket-level failure is
    normalized to :class:`WorkerDied`, which the coordinator treats as the
    revive trigger."""

    def __init__(self, host: str, port: int, timeout: float = 300.0) -> None:
        self.host, self.port = host, port
        try:
            self._sock = socket.create_connection((host, port), timeout=10.0)
        except OSError as e:
            raise WorkerDied(f"connect {host}:{port}: {e}") from e
        self._sock.settimeout(timeout)
        self._rfile = self._sock.makefile("rb")

    def request(self, msg: dict[str, Any]) -> dict[str, Any]:
        """Send one op, block for its reply line."""
        try:
            self._sock.sendall((json.dumps(msg) + "\n").encode())
            line = self._rfile.readline()
        except OSError as e:
            raise WorkerDied(f"{self.host}:{self.port}: {e}") from e
        if not line:
            raise WorkerDied(f"{self.host}:{self.port}: connection closed")
        return json.loads(line)

    def close(self) -> None:
        """Drop the connection (idempotent; socket errors are swallowed)."""
        try:
            self._rfile.close()
            self._sock.close()
        except OSError:
            pass


@dataclasses.dataclass
class WorkerSpec:
    """Per-worker deployment knobs — the heterogeneous-host axis. ``backend``
    feeds ``OptRequest.backend`` (xla | pallas evaluator per host) and
    ``algo`` the per-host policy, so a federation can mix solver kinds the
    way popt4jlib mixed DGA/DPSO servers."""

    backend: str = "xla"
    algo: str = "de"


@dataclasses.dataclass
class FederationConfig:
    """The whole federation as data: every job seed and routing decision is
    derived from these fields, which is what makes the final incumbent
    reproducible across worker deaths."""

    fn: str = "rastrigin"
    dim: int = 8
    workers: tuple[WorkerSpec, ...] = (WorkerSpec(), WorkerSpec())
    legs: int = 3                  # coordinator rounds (warm-routing hops)
    evals_per_leg: int = 4000
    seed: int = 0
    pop: int = 32
    n_islands: int = 2
    sync_every: int = 5
    checkpoint_root: str = "fed_ckpt"
    result_timeout: float = 300.0  # blocking-result deadline per job

    def job_seed(self, leg: int, worker: int) -> int:
        """Deterministic per-(leg, worker) seed — never reused across legs,
        so no leg replays another's trajectory."""
        return self.seed * 1_000_003 + leg * 1_009 + worker

    def job_id(self, leg: int, worker: int) -> str:
        """Stable id a revived worker resumes (or recomputes) the job under."""
        return f"fed-l{leg}-w{worker}"

    def request_dict(self, leg: int, worker: int,
                     warm: list[list[float]]) -> dict[str, Any]:
        """The JSONL ``submit`` request for one (leg, worker) job: the
        worker's backend/algo, the deterministic seed, and the warm
        immigrants routed to it from the previous leg."""
        spec = self.workers[worker]
        return {
            "fn": self.fn, "algo": spec.algo, "dim": self.dim,
            "pop": self.pop, "n_islands": self.n_islands,
            "sync_every": self.sync_every, "max_evals": self.evals_per_leg,
            "backend": spec.backend, "seed": self.job_seed(leg, worker),
            "warm": warm,
        }


@dataclasses.dataclass
class FederationResult:
    """Outcome of a federated run: the global incumbent plus the per-leg
    per-worker table and the fault-tolerance counters."""

    value: float
    arg: list[float]
    legs: list[list[dict[str, Any]]]   # legs[leg][worker] -> result reply
    revived: int                        # worker respawns (death mid-leg)
    resubmitted: int                    # jobs recomputed (no checkpoint row)


class _Worker:
    """A spawned ``opt_serve`` subprocess + its JSONL client + the checkpoint
    directory its revives resume from."""

    def __init__(self, index: int, ckpt_dir: str) -> None:
        self.index = index
        self.ckpt_dir = ckpt_dir
        self.proc: subprocess.Popen | None = None
        self.client: JsonlClient | None = None
        self.port: int | None = None

    def spawn(self, resume: bool = False) -> None:
        """Start (or restart) the worker process on an ephemeral port.

        ``resume=True`` adds ``--resume-dir`` so the scheduler restores every
        interrupted bucket run from this worker's own checkpoint store before
        serving — the death/rejoin half of the federation contract."""
        cmd = [sys.executable, "-m", "repro.launch.opt_serve",
               "--tcp", "0", "--workers", "1", "--flush-ms", "10",
               "--checkpoint-dir", self.ckpt_dir, "--checkpoint-every", "1"]
        if resume:
            cmd += ["--resume-dir", self.ckpt_dir]
        self.proc = subprocess.Popen(
            cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE,
            env=dict(os.environ))
        self.port = _wait_listening(self.proc.stderr)
        self.client = JsonlClient("127.0.0.1", self.port)

    def kill(self) -> None:
        """SIGKILL — the fault-injection hook tests and the demo use."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.send_signal(signal.SIGKILL)
            self.proc.wait()

    def shutdown(self) -> None:
        if self.client is not None:
            try:
                self.client.request({"op": "quit"})
            except WorkerDied:
                pass
            self.client.close()
        if self.proc is not None:
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()


def _wait_listening(stderr: IO[bytes], timeout: float = 120.0) -> int:
    """Parse the worker's ephemeral port from its ``listening on`` banner
    (the resume summary line, when present, precedes it)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        line = stderr.readline()
        if not line:
            raise WorkerDied("worker exited before listening")
        m = _LISTEN_RE.search(line.decode("utf-8", "replace"))
        if m:
            return int(m.group(2))
    raise WorkerDied("worker never reported a listening port")


class FederationCoordinator:
    """Drives a :class:`FederationConfig` to completion over local worker
    subprocesses, reviving any worker whose socket dies mid-leg."""

    def __init__(self, cfg: FederationConfig) -> None:
        self.cfg = cfg
        self.workers = [
            _Worker(i, os.path.join(cfg.checkpoint_root, f"worker{i}"))
            for i in range(len(cfg.workers))]
        self.n_revived = 0
        self.n_resubmitted = 0
        # test/demo fault hook: called as fault_hook(leg) after the leg's
        # submits land but before results are collected
        self.fault_hook = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        """Spawn every worker process and wait for their TCP banners."""
        for w in self.workers:
            os.makedirs(w.ckpt_dir, exist_ok=True)
            w.spawn()

    def close(self) -> None:
        """Quit every worker (drains in-flight buckets) and reap it."""
        for w in self.workers:
            w.shutdown()

    # -- fault tolerance ---------------------------------------------------

    def _revive(self, w: _Worker) -> None:
        """Respawn a dead worker with ``--resume-dir``: interrupted bucket
        runs come back under their original job ids (checkpoint manifests,
        DESIGN.md §12) and finish bit-identically."""
        self.n_revived += 1
        if w.client is not None:
            w.client.close()
        if w.proc is not None and w.proc.poll() is None:
            w.proc.kill()
            w.proc.wait()
        w.spawn(resume=True)

    def _collect(self, w: _Worker, leg: int,
                 req: dict[str, Any]) -> dict[str, Any]:
        """Blocking result fetch with revive-on-death. Three outcomes per
        attempt: a final reply (done); ``unknown-id`` (the job never reached
        a checkpoint, or finished and was evicted by the crash) — resubmit
        the same request under the same id and recompute the identical
        fixed-seed answer; a dead socket — revive from checkpoints and
        retry."""
        jid = self.cfg.job_id(leg, w.index)
        for _ in range(4):                 # spawn->die loops are bounded
            try:
                reply = w.client.request(
                    {"op": "result", "id": jid})
                if reply.get("error") == "unknown-id":
                    self.n_resubmitted += 1
                    w.client.request(
                        {"op": "submit", "id": jid, "request": req})
                    reply = w.client.request({"op": "result", "id": jid})
                if reply.get("status") == "done":
                    return reply
                raise WorkerDied(f"job {jid} ended {reply!r}")
            except WorkerDied:
                self._revive(w)
        raise WorkerDied(f"worker {w.index} kept dying on job {jid}")

    # -- the run -----------------------------------------------------------

    def run(self) -> FederationResult:
        """Execute every leg: submit one job per worker, collect, route each
        worker's best candidate to its ring successor as the next leg's warm
        immigrants. Returns the deterministic global incumbent."""
        cfg = self.cfg
        n = len(self.workers)
        warm: list[list[list[float]]] = [[] for _ in range(n)]
        legs: list[list[dict[str, Any]]] = []
        best_val, best_arg = float("inf"), None
        for leg in range(cfg.legs):
            reqs = [cfg.request_dict(leg, i, warm[i]) for i in range(n)]
            for w, req in zip(self.workers, reqs):
                try:
                    w.client.request({"op": "submit",
                                      "id": cfg.job_id(leg, w.index),
                                      "request": req})
                except WorkerDied:
                    self._revive(w)   # resubmitted via unknown-id in _collect
            if self.fault_hook is not None:
                self.fault_hook(leg)
            rows = [self._collect(w, leg, req)
                    for w, req in zip(self.workers, reqs)]
            legs.append(rows)
            for r in rows:
                if r["value"] < best_val:
                    best_val, best_arg = r["value"], r["arg"]
            # ring routing: worker i's best seeds worker (i+1)'s next leg
            warm = [[rows[(i - 1) % n]["arg"]] for i in range(n)]
        return FederationResult(value=best_val, arg=best_arg, legs=legs,
                                revived=self.n_revived,
                                resubmitted=self.n_resubmitted)


def federate(cfg: FederationConfig) -> FederationResult:
    """Run one federation start-to-finish (spawn, legs, shutdown) — the
    programmatic entry point ``tests/test_federation.py`` drives."""
    coord = FederationCoordinator(cfg)
    coord.start()
    try:
        return coord.run()
    finally:
        coord.close()


def main(argv: list[str] | None = None) -> None:
    """CLI entry point — the docs walkthrough and the CI federation smoke."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-workers", type=int, default=2)
    ap.add_argument("--legs", type=int, default=3)
    ap.add_argument("--fn", default="rastrigin")
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--evals-per-leg", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--n-islands", type=int, default=2)
    ap.add_argument("--backends", default="xla",
                    help="comma list cycled over workers (heterogeneous "
                         "hosts), e.g. xla,pallas")
    ap.add_argument("--algos", default="de",
                    help="comma list cycled over workers, e.g. de,pso")
    ap.add_argument("--checkpoint-root", default="fed_ckpt")
    ap.add_argument("--demo-kill", default=None, metavar="LEG:WORKER",
                    help="SIGKILL worker W after leg L's submits land — the "
                         "kill/resume demo; the run still finishes with the "
                         "uninterrupted incumbent")
    args = ap.parse_args(argv)

    backends = args.backends.split(",")
    algos = args.algos.split(",")
    cfg = FederationConfig(
        fn=args.fn, dim=args.dim, legs=args.legs,
        evals_per_leg=args.evals_per_leg, seed=args.seed, pop=args.pop,
        n_islands=args.n_islands, checkpoint_root=args.checkpoint_root,
        workers=tuple(WorkerSpec(backend=backends[i % len(backends)],
                                 algo=algos[i % len(algos)])
                      for i in range(args.n_workers)))
    coord = FederationCoordinator(cfg)
    if args.demo_kill:
        kleg, kworker = (int(x) for x in args.demo_kill.split(":"))

        def fault(leg: int) -> None:
            if leg == kleg:
                print(f"[federate] SIGKILL worker {kworker} at leg {leg}",
                      file=sys.stderr, flush=True)
                coord.workers[kworker].kill()

        coord.fault_hook = fault
    coord.start()
    try:
        res = coord.run()
    finally:
        coord.close()
    print(json.dumps({"value": res.value, "arg": res.arg,
                      "legs": len(res.legs), "revived": res.revived,
                      "resubmitted": res.resubmitted}))


if __name__ == "__main__":
    main()
