"""Production mesh factory.

Defined as a FUNCTION so importing this module never touches jax device state
(jax locks the device count on first backend initialization — dryrun.py must
set XLA_FLAGS before anything imports jax).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis carries data parallelism across pods (gradient all-reduce crosses the
inter-pod links exactly once per step).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """The axes that carry batch/population parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
