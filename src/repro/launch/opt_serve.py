"""Multi-job optimization service — the popt4jlib ``PDBTExecSingleCltWrkInitSrv``
client/server loop over the shape-bucketed scheduler (DESIGN.md §5, hardened
per §12: worker-pool flushes, streaming progress, cancellation, backpressure
and checkpoint/resume).

One JSON object per line (JSONL), over stdin/stdout (default) or TCP
(``--tcp PORT``). The ops mirror the Java server's client protocol
(submit work / poll / fetch results / shutdown):

    {"op": "submit", "request": {"fn": "rastrigin", "algo": "de", "dim": 8,
                                 "max_evals": 4000, "seed": 1}}
        -> {"id": "job0", "status": "queued"}
    {"op": "submit", "priority": 5, "request": {...}}
        -> priority lane: the worker pool runs higher-priority buckets first
    {"op": "poll", "id": "job0"}      -> {"id": "job0", "status": "running",
                                          "round": 12, "n_rounds": 40,
                                          "best_val": ..., "evals_done": ...}
    {"op": "result", "id": "job0"}    -> {"id": "job0", "status": "done",
                                          "value": ..., "arg": [...], "n_evals": ...}
    {"op": "cancel", "id": "job0"}    -> cooperative preemption at the next
                                         round boundary; partial result kept
    {"op": "status"}                  -> per-bucket {"counts": {...},
                                         "sync_policy": ...} + worker-pool
                                         "queue_depth" (accepted, unstarted)
    {"op": "flush"}                   -> {"flushed": N}
    {"op": "stats"}                   -> scheduler + queue counters
    {"op": "quit"}                    -> {"bye": true}

Unknown or already-evicted job ids yield a structured
``{"error": "unknown-id", "id": ...}`` reply; when ``--max-pending`` is set,
submissions over capacity are load-shed with
``{"error": "overloaded", "retry_after_ms": ...}``.

With ``--workers N`` (the production shape) bucket flushes run on a bounded
worker-thread pool with priority lanes, so a slow bucket never blocks the
request loop — submit/poll/cancel/status stay responsive while long jobs
stream per-round progress. ``--checkpoint-dir`` snapshots every running
bucket's engine state each ``--checkpoint-every`` rounds through
``checkpoint/store.py``; after a crash or SIGKILL, restarting with
``--resume-dir`` restores the interrupted runs under their original job ids
and finishes them bit-identically to an uninterrupted fixed-seed run
(DESIGN.md §12). With ``--workers 0`` the service keeps the legacy blocking
behavior — one global op lock, flushes inline — which doubles as the soak
benchmark's baseline (``benchmarks/service.py``).

Hybrid memetic jobs (DESIGN.md §6) are plain requests with polish fields —
they bucket separately from plain jobs because polish parameters join the
compiled shape-class:

    {"op": "submit", "request": {"fn": "rosenbrock", "dim": 12, "max_evals": 20000,
                                 "polish": "asd", "polish_every": 3,
                                 "polish_topk": 2, "polish_steps": 2, "seed": 0}}

Heterogeneous portfolio jobs (DESIGN.md §10) submit a per-island policy list
(cycled over the islands) instead of a single ``algo``; ``params`` then maps
policy name -> kwargs. The portfolio joins the shape-class, so two different
portfolios never collide into one compiled bucket:

    {"op": "submit", "request": {"fn": "rastrigin", "dim": 12, "n_islands": 6,
                                 "portfolio": ["de", "pso", "sa"],
                                 "params": {"sa": {"T0": 100.0}},
                                 "max_evals": 20000, "seed": 0}}

Device-sharded jobs (DESIGN.md §8) work the same way — ``devices`` is an
ordinary request field that joins the shape-class, so sharded and
single-device traffic never mix buckets. Sharded buckets run device-resident
(no host round loop inside ``shard_map``) and portfolio buckets stay
resident to preserve bit-identity (DESIGN.md §12): both stream no mid-run
progress and refuse mid-run cancellation with a structured error.

Batching policy (host-side queue): a bucket is dispatched when it reaches
``--max-batch`` queued jobs, when its oldest job ages past the ``--flush-ms``
deadline, or when a client forces it via ``result``/``flush``. Everything the
deadline window packs into one bucket runs as a single jitted jobs-axis
dispatch.

    PYTHONPATH=src python -m repro.launch.opt_serve --flush-ms 50 <<'EOF'
    {"op": "submit", "request": {"fn": "sphere", "dim": 4, "max_evals": 2000, "seed": 0}}
    {"op": "result", "id": "job0"}
    EOF
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import select
import socketserver
import sys
import threading
import time
from typing import Any

from repro.core.api import OptRequest
from repro.core.scheduler import (SchedulerOverloaded, ShapeBucketScheduler,
                                  UnknownJob)


class OptimizationService:
    """Host-side queue + deadline-based flush around ShapeBucketScheduler.

    Thread-safe: TCP mode serves concurrent clients against one scheduler
    (the Java server's single-client-at-a-time restriction is lifted — jobs
    from different connections share buckets). With ``workers > 0`` the
    scheduler runs bucket flushes on its priority worker pool and ops are
    lock-free at this layer; with ``workers == 0`` a single op lock
    serializes everything and flushes run inline (the legacy blocking
    behavior, kept as the soak benchmark's baseline).
    """

    def __init__(self, scheduler: ShapeBucketScheduler | None = None,
                 max_batch: int = 32, flush_ms: float = 50.0,
                 workers: int = 0, max_pending: int = 0,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 8) -> None:
        self.scheduler = scheduler or ShapeBucketScheduler(
            workers=workers, max_pending=max_pending,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every)
        self.max_batch = max_batch
        self.flush_ms = flush_ms
        self._lock = threading.Lock()

    def _oplock(self):
        """The global op lock in blocking mode; a no-op with a worker pool
        (the scheduler is internally thread-safe and ops return quickly)."""
        if self.scheduler.workers:
            return contextlib.nullcontext()
        return self._lock

    # -- protocol ----------------------------------------------------------

    def handle(self, msg: dict[str, Any]) -> dict[str, Any]:
        """Execute one protocol op; always returns a JSON-able reply."""
        try:
            # poll is a dict lookup + attribute reads (GIL-atomic): answer
            # without any lock so status/progress stay responsive while a
            # bucket dispatch (compile + run) is in flight elsewhere.
            if msg.get("op") == "poll":
                resp = self.scheduler.poll(msg["id"])
                return {"id": msg["id"], "status": resp.status,
                        **resp.progress_dict()}
            if msg.get("op") == "result":
                # fetch-once: the record is evicted so a long-lived server's
                # job table stays bounded; a second result/poll for the id
                # yields the structured unknown-id error. In pool mode this
                # waits on the job's completion event WITHOUT any service
                # lock, so other clients keep being served meanwhile; in
                # blocking mode the lock serializes the inline flush (the
                # legacy behavior the soak benchmark uses as its baseline).
                with self._oplock():
                    resp = self.scheduler.result(msg["id"], evict=True)
                return resp.to_dict()
            with self._oplock():
                return self._dispatch(msg)
        except UnknownJob:
            return {"error": "unknown-id", "id": msg.get("id")}
        except SchedulerOverloaded as e:
            return {"error": "overloaded",
                    "retry_after_ms": e.retry_after_ms}
        except Exception as e:  # noqa: BLE001 — protocol errors go to the client
            return {"error": f"{type(e).__name__}: {e}"}

    def _dispatch(self, msg: dict[str, Any]) -> dict[str, Any]:
        op = msg.get("op")
        sched = self.scheduler
        if op == "submit":
            req = OptRequest.from_dict(msg["request"])
            job_id = sched.submit(req, msg.get("id"),
                                  priority=int(msg.get("priority", 0)))
            resp = {"id": job_id, "status": "queued"}
            key = req.shape_class()
            if sched.pending_count(key) >= self.max_batch:
                sched.flush_bucket(key)
                resp["status"] = sched.poll(job_id).status
            return resp
        if op == "cancel":
            return sched.cancel(msg["id"])
        if op == "status":
            return {"buckets": sched.bucket_status(),
                    "queue_depth": sched.queue_depth()}
        if op == "flush":
            return {"flushed": sched.flush()}
        if op == "stats":
            return dict(sched.stats(), max_batch=self.max_batch,
                        flush_ms=self.flush_ms)
        if op == "quit":
            if sched.workers:
                sched.drain()       # finish in-flight work before goodbye
            else:
                sched.flush()
            return {"bye": True}
        raise ValueError(f"unknown op {op!r}")

    # -- deadline flush ----------------------------------------------------

    def tick(self, now: float | None = None) -> int:
        """Dispatch buckets whose oldest job aged past the deadline."""
        now = time.monotonic() if now is None else now
        n = 0
        with self._oplock():
            for key, _, oldest in self.scheduler.pending_buckets():
                if (now - oldest) * 1e3 >= self.flush_ms:
                    n += len(self.scheduler.flush_bucket(key))
        return n

    def next_deadline(self) -> float | None:
        """Monotonic time of the earliest pending flush, or None if idle."""
        buckets = self.scheduler.pending_buckets()
        if not buckets:
            return None
        return min(oldest for _, _, oldest in buckets) + self.flush_ms / 1e3


def _handle_line(service: OptimizationService, line: str) -> tuple[dict, bool]:
    """(reply, is_quit) for one JSONL request line."""
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as e:
        return {"error": f"bad json: {e}"}, False
    if not isinstance(msg, dict):          # e.g. a bare `42` — valid JSON,
        return {"error": "request must be a JSON object"}, False  # not an op
    return service.handle(msg), msg.get("op") == "quit"


def serve_stdin(service: OptimizationService) -> None:
    """stdin-JSONL loop: select() on the raw fd with the flush deadline as
    timeout, so queued buckets dispatch even while the client is silent.
    Reads unbuffered (os.read + explicit line buffer) — buffered readline
    would swallow ops that arrive several-per-write and leave them pending
    while select() sees a quiet fd."""
    out, fd = sys.stdout, sys.stdin.fileno()
    buf = b""
    while True:
        while b"\n" in buf:               # drain buffered ops before select
            raw, buf = buf.split(b"\n", 1)
            line = raw.decode("utf-8", "replace").strip()
            if not line:
                continue
            reply, quit_ = _handle_line(service, line)
            print(json.dumps(reply), file=out, flush=True)
            if quit_:
                return
        deadline = service.next_deadline()
        timeout = None if deadline is None else max(0.0, deadline - time.monotonic())
        ready, _, _ = select.select([fd], [], [], timeout)
        if not ready:
            service.tick()
            continue
        chunk = os.read(fd, 1 << 16)
        if not chunk:                     # EOF: run what's left, then exit
            service.handle({"op": "flush"})
            if service.scheduler.workers:
                service.scheduler.drain()
            return
        buf += chunk


class _LineHandler(socketserver.StreamRequestHandler):
    def handle(self) -> None:  # one JSONL session per connection
        service: OptimizationService = self.server.service  # type: ignore[attr-defined]
        for raw in self.rfile:
            line = raw.decode("utf-8", "replace").strip()
            if not line:
                continue
            reply, quit_ = _handle_line(service, line)
            self.wfile.write((json.dumps(reply) + "\n").encode())
            self.wfile.flush()
            if quit_:
                return


def serve_tcp(service: OptimizationService, host: str, port: int) -> None:
    """TCP-JSONL server: threaded clients + a daemon ticking the deadline."""

    class Server(socketserver.ThreadingTCPServer):
        allow_reuse_address = True
        daemon_threads = True

    def ticker() -> None:
        while True:
            time.sleep(max(service.flush_ms / 2e3, 1e-3))
            service.tick()

    threading.Thread(target=ticker, daemon=True).start()
    with Server((host, port), _LineHandler) as srv:
        srv.service = service  # type: ignore[attr-defined]
        print(f"[opt_serve] listening on {host}:{srv.server_address[1]}",
              file=sys.stderr, flush=True)
        srv.serve_forever()


def main() -> None:
    """CLI entry point: parse flags, resume interrupted runs when asked, then
    serve JSONL over stdin or TCP."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-batch", type=int, default=32,
                    help="flush a bucket as soon as it holds this many jobs")
    ap.add_argument("--flush-ms", type=float, default=50.0,
                    help="deadline: max queueing delay before a bucket runs")
    ap.add_argument("--tcp", type=int, default=None, metavar="PORT",
                    help="serve TCP-JSONL on this port instead of stdin")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--workers", type=int, default=2,
                    help="bucket-flush worker threads; 0 = legacy blocking "
                         "mode (flushes inline under one global op lock)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="backpressure: load-shed submissions once this many "
                         "jobs are queued (0 = unbounded)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="snapshot running buckets' engine state under DIR")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="sync rounds between bucket state snapshots")
    ap.add_argument("--resume-dir", default=None, metavar="DIR",
                    help="restore interrupted runs from DIR at startup "
                         "(also becomes the checkpoint dir unless one is set)")
    args = ap.parse_args()

    ckpt = args.checkpoint_dir or args.resume_dir
    service = OptimizationService(
        max_batch=args.max_batch, flush_ms=args.flush_ms,
        workers=args.workers, max_pending=args.max_pending,
        checkpoint_dir=ckpt, checkpoint_every=args.checkpoint_every)
    if args.resume_dir is not None:
        summary = service.scheduler.resume(args.resume_dir)
        print(f"[opt_serve] resume: {json.dumps(summary)}",
              file=sys.stderr, flush=True)
    if args.tcp is not None:
        serve_tcp(service, args.host, args.tcp)
    else:
        serve_stdin(service)


if __name__ == "__main__":
    main()
