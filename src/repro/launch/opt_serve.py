"""Multi-job optimization service — the popt4jlib ``PDBTExecSingleCltWrkInitSrv``
client/server loop over the shape-bucketed scheduler (DESIGN.md §5).

One JSON object per line (JSONL), over stdin/stdout (default) or TCP
(``--tcp PORT``). The ops mirror the Java server's client protocol
(submit work / poll / fetch results / shutdown):

    {"op": "submit", "request": {"fn": "rastrigin", "algo": "de", "dim": 8,
                                 "max_evals": 4000, "seed": 1}}
        -> {"id": "job0", "status": "queued"}
    {"op": "poll", "id": "job0"}      -> {"id": "job0", "status": "queued|running|done|error"}
    {"op": "result", "id": "job0"}    -> {"id": "job0", "status": "done",
                                          "value": ..., "arg": [...], "n_evals": ...}
    {"op": "flush"}                   -> {"flushed": N}
    {"op": "stats"}                   -> scheduler + queue counters
    {"op": "quit"}                    -> {"bye": true}

Hybrid memetic jobs (DESIGN.md §6) are plain requests with polish fields —
they bucket separately from plain jobs because polish parameters join the
compiled shape-class:

    {"op": "submit", "request": {"fn": "rosenbrock", "dim": 12, "max_evals": 20000,
                                 "polish": "asd", "polish_every": 3,
                                 "polish_topk": 2, "polish_steps": 2, "seed": 0}}

Heterogeneous portfolio jobs (DESIGN.md §10) submit a per-island policy list
(cycled over the islands) instead of a single ``algo``; ``params`` then maps
policy name -> kwargs. The portfolio joins the shape-class, so two different
portfolios never collide into one compiled bucket:

    {"op": "submit", "request": {"fn": "rastrigin", "dim": 12, "n_islands": 6,
                                 "portfolio": ["de", "pso", "sa"],
                                 "params": {"sa": {"T0": 100.0}},
                                 "max_evals": 20000, "seed": 0}}

Device-sharded jobs (DESIGN.md §8) work the same way — ``devices`` is an
ordinary request field that joins the shape-class, so sharded and
single-device traffic never mix buckets and the service loop needs no
changes. A request the host cannot place (more devices than visible) errors
in its own bucket without disturbing other clients:

    {"op": "submit", "request": {"fn": "rastrigin", "dim": 16, "n_islands": 8,
                                 "devices": 8, "max_evals": 40000, "seed": 0}}

Batching policy (host-side queue): a bucket is dispatched when it reaches
``--max-batch`` queued jobs, when its oldest job ages past the ``--flush-ms``
deadline, or when a client forces it via ``result``/``flush``. Everything the
deadline window packs into one bucket runs as a single jitted jobs-axis
dispatch.

    PYTHONPATH=src python -m repro.launch.opt_serve --flush-ms 50 <<'EOF'
    {"op": "submit", "request": {"fn": "sphere", "dim": 4, "max_evals": 2000, "seed": 0}}
    {"op": "result", "id": "job0"}
    EOF
"""
from __future__ import annotations

import argparse
import json
import os
import select
import socketserver
import sys
import threading
import time
from typing import Any

from repro.core.api import OptRequest
from repro.core.scheduler import ShapeBucketScheduler


class OptimizationService:
    """Host-side queue + deadline-based flush around ShapeBucketScheduler.

    Thread-safe: TCP mode serves concurrent clients against one scheduler
    (the Java server's single-client-at-a-time restriction is lifted — jobs
    from different connections share buckets).
    """

    def __init__(self, scheduler: ShapeBucketScheduler | None = None,
                 max_batch: int = 32, flush_ms: float = 50.0) -> None:
        self.scheduler = scheduler or ShapeBucketScheduler()
        self.max_batch = max_batch
        self.flush_ms = flush_ms
        self._lock = threading.Lock()

    # -- protocol ----------------------------------------------------------

    def handle(self, msg: dict[str, Any]) -> dict[str, Any]:
        """Execute one protocol op; always returns a JSON-able reply."""
        try:
            # poll is a single dict lookup + attribute read (GIL-atomic):
            # answer without the lock so status stays responsive while
            # another client's bucket dispatch (compile + run) holds it.
            # stats iterates the scheduler's dicts, so it must take the lock.
            if msg.get("op") == "poll":
                return {"id": msg["id"],
                        "status": self.scheduler.poll(msg["id"]).status}
            with self._lock:
                return self._dispatch(msg)
        except Exception as e:  # noqa: BLE001 — protocol errors go to the client
            return {"error": f"{type(e).__name__}: {e}"}

    def _dispatch(self, msg: dict[str, Any]) -> dict[str, Any]:
        op = msg.get("op")
        sched = self.scheduler
        if op == "submit":
            req = OptRequest.from_dict(msg["request"])
            job_id = sched.submit(req, msg.get("id"))
            resp = {"id": job_id, "status": "queued"}
            key = req.shape_class()
            if sched.pending_count(key) >= self.max_batch:
                sched.flush_bucket(key)
                resp["status"] = sched.poll(job_id).status
            return resp
        if op == "result":
            # fetch-once: the record is evicted so a long-lived server's job
            # table stays bounded; a second result/poll for the id errors
            return sched.result(msg["id"], evict=True).to_dict()
        if op == "flush":
            return {"flushed": sched.flush()}
        if op == "stats":
            return dict(sched.stats(), max_batch=self.max_batch,
                        flush_ms=self.flush_ms)
        if op == "quit":
            sched.flush()
            return {"bye": True}
        raise ValueError(f"unknown op {op!r}")

    # -- deadline flush ----------------------------------------------------

    def tick(self, now: float | None = None) -> int:
        """Flush buckets whose oldest job aged past the deadline."""
        now = time.monotonic() if now is None else now
        n = 0
        with self._lock:
            for key, _, oldest in self.scheduler.pending_buckets():
                if (now - oldest) * 1e3 >= self.flush_ms:
                    n += len(self.scheduler.flush_bucket(key))
        return n

    def next_deadline(self) -> float | None:
        """Monotonic time of the earliest pending flush, or None if idle."""
        with self._lock:
            buckets = self.scheduler.pending_buckets()
        if not buckets:
            return None
        return min(oldest for _, _, oldest in buckets) + self.flush_ms / 1e3


def _handle_line(service: OptimizationService, line: str) -> tuple[dict, bool]:
    """(reply, is_quit) for one JSONL request line."""
    try:
        msg = json.loads(line)
    except json.JSONDecodeError as e:
        return {"error": f"bad json: {e}"}, False
    if not isinstance(msg, dict):          # e.g. a bare `42` — valid JSON,
        return {"error": "request must be a JSON object"}, False  # not an op
    return service.handle(msg), msg.get("op") == "quit"


def serve_stdin(service: OptimizationService) -> None:
    """stdin-JSONL loop: select() on the raw fd with the flush deadline as
    timeout, so queued buckets dispatch even while the client is silent.
    Reads unbuffered (os.read + explicit line buffer) — buffered readline
    would swallow ops that arrive several-per-write and leave them pending
    while select() sees a quiet fd."""
    out, fd = sys.stdout, sys.stdin.fileno()
    buf = b""
    while True:
        while b"\n" in buf:               # drain buffered ops before select
            raw, buf = buf.split(b"\n", 1)
            line = raw.decode("utf-8", "replace").strip()
            if not line:
                continue
            reply, quit_ = _handle_line(service, line)
            print(json.dumps(reply), file=out, flush=True)
            if quit_:
                return
        deadline = service.next_deadline()
        timeout = None if deadline is None else max(0.0, deadline - time.monotonic())
        ready, _, _ = select.select([fd], [], [], timeout)
        if not ready:
            service.tick()
            continue
        chunk = os.read(fd, 1 << 16)
        if not chunk:                     # EOF: run what's left, then exit
            service.handle({"op": "flush"})
            return
        buf += chunk


class _LineHandler(socketserver.StreamRequestHandler):
    def handle(self) -> None:  # one JSONL session per connection
        service: OptimizationService = self.server.service  # type: ignore[attr-defined]
        for raw in self.rfile:
            line = raw.decode("utf-8", "replace").strip()
            if not line:
                continue
            reply, quit_ = _handle_line(service, line)
            self.wfile.write((json.dumps(reply) + "\n").encode())
            self.wfile.flush()
            if quit_:
                return


def serve_tcp(service: OptimizationService, host: str, port: int) -> None:
    """TCP-JSONL server: threaded clients + a daemon ticking the deadline."""

    class Server(socketserver.ThreadingTCPServer):
        allow_reuse_address = True
        daemon_threads = True

    def ticker() -> None:
        while True:
            time.sleep(max(service.flush_ms / 2e3, 1e-3))
            service.tick()

    threading.Thread(target=ticker, daemon=True).start()
    with Server((host, port), _LineHandler) as srv:
        srv.service = service  # type: ignore[attr-defined]
        print(f"[opt_serve] listening on {host}:{srv.server_address[1]}",
              file=sys.stderr, flush=True)
        srv.serve_forever()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-batch", type=int, default=32,
                    help="flush a bucket as soon as it holds this many jobs")
    ap.add_argument("--flush-ms", type=float, default=50.0,
                    help="deadline: max queueing delay before a bucket runs")
    ap.add_argument("--tcp", type=int, default=None, metavar="PORT",
                    help="serve TCP-JSONL on this port instead of stdin")
    ap.add_argument("--host", default="127.0.0.1")
    args = ap.parse_args()

    service = OptimizationService(max_batch=args.max_batch,
                                  flush_ms=args.flush_ms)
    if args.tcp is not None:
        serve_tcp(service, args.host, args.tcp)
    else:
        serve_stdin(service)


if __name__ == "__main__":
    main()
