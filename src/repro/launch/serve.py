"""Serving launcher: prefill + batched decode loop with a static KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --prompt-len 16 --decode-steps 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_decode
from repro.models import init_decode_state, init_params
from repro.models.transformer import decode_step


def serve(cfg, batch: int, prompt_len: int, decode_steps: int,
          temperature: float = 0.0):
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    max_len = prompt_len + decode_steps + 1
    state = init_decode_state(cfg, batch, max_len)
    step = jax.jit(make_decode_step(cfg))
    prefill_step = jax.jit(make_prefill_decode(cfg))

    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (batch, prompt_len), 0, cfg.vocab)
    # batched teacher-forced prefill: the whole prompt fills the cache in one
    # dispatch (attention archs in parallel, recurrent archs via an in-jit
    # scan) instead of O(prompt_len) per-token host round-trips
    t0 = time.time()
    logits, state = prefill_step(params, state, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(decode_steps):
        tokens.append(tok)
        logits, state = step(params, state, {"tokens": tok})
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, :cfg.vocab] / temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    out = jnp.concatenate(tokens, axis=1)
    return out, t_prefill, t_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend == "audio_stub":
        raise SystemExit("audio arch serving needs frame embeddings; use the "
                         "decode dry-run cells for musicgen")
    out, tp, td = serve(cfg, args.batch, args.prompt_len, args.decode_steps,
                        args.temperature)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"decoded={out.shape[1]} tokens")
    print(f"[serve] prefill {tp*1e3:.0f} ms, decode "
          f"{td/args.decode_steps*1e3:.1f} ms/token "
          f"({args.batch*args.decode_steps/td:.0f} tok/s)")
    print(f"[serve] sample row: {out[0, :16].tolist()}")


if __name__ == "__main__":
    main()
