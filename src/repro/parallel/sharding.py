"""Sharding rules: PartitionSpec pytrees for params, optimizer state, batches
and decode caches, for both production meshes.

Strategy (see DESIGN.md + EXPERIMENTS.md §Perf for measured trade-offs):

  tp        Megatron 1-D tensor parallelism over the ``model`` axis:
            attention heads / FFN hidden / vocab are model-sharded; weights
            replicated over (pod, data); batch over (pod, data).
  tp+fsdp   same compute sharding, but master weights and Adam moments are
            additionally sharded over the data axes (ZeRO-3 storage); XLA
            all-gathers weights at use and reduce-scatters gradients.

Edge rules (driven by divisibility against the fixed 16-wide model axis):
  * KV-head projections are model-sharded only when n_kv_heads % 16 == 0,
    else replicated (GQA archs with kv=8: the KV tensors are small).
  * Archs with n_heads % 16 != 0 (musicgen: 24H) replicate attention weights;
    their attention parallelism comes from batch/sequence sharding.
  * MoE experts shard over ``model`` when num_experts % 16 == 0 (llama4);
    otherwise (qwen2-moe: 60) experts stay local and the per-expert hidden
    dim shards over ``model``.
  * Decode KV caches shard the *sequence* dim over ``model`` (sequence-
    parallel decode attention) — KV-head counts never divide 16 uniformly,
    sequence lengths always do.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any
TP = 16  # fixed model-axis width of the production meshes


def _dax(mesh_axes: tuple[str, ...]) -> tuple[str, ...] | str:
    return ("pod", "data") if "pod" in mesh_axes else "data"


def _div(n: int, by: int) -> bool:
    return n % by == 0


def _all_axes(mesh_axes: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(mesh_axes)  # ("pod","data","model") or ("data","model")


def _dp_zero1_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...]) -> PyTree:
    """sharding_mode="dp+zero1": pure data parallelism over EVERY mesh axis
    (batch over (pod, data, model)); master params + Adam moments sharded over
    all chips on each weight's largest dim (ZeRO-1). Compute weights are
    replicated (gathered once per step by the compute-spec constraint) — for
    sub-3B archs this trades a small weight all-gather for the elimination of
    every per-layer tensor-parallel all-reduce."""
    allax = _all_axes(mesh_axes)
    n = 1
    for a in allax:
        n *= {"pod": 2}.get(a, 16)

    def biggest_dim_spec(arr) -> P:
        dims = list(arr.shape)
        # shard the largest dim divisible by the full device count
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] % n == 0:
                return P(*[allax if j == i else None for j in range(len(dims))])
        for i in order:  # fall back to data axes only
            nd = n // 16
            if nd > 1 and dims[i] % nd == 0:
                dx = tuple(a for a in allax if a != "model")
                return P(*[dx if j == i else None for j in range(len(dims))])
        return P(*([None] * len(dims)))

    from repro.models.transformer import init_params
    pshape = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree.map(biggest_dim_spec, pshape)


def param_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...]) -> PyTree:
    """PartitionSpec pytree matching init_params(cfg) structure."""
    if cfg.sharding_mode == "dp+zero1":
        return _dp_zero1_specs(cfg, mesh_axes)
    dax = _dax(mesh_axes)
    fsdp = cfg.sharding_mode == "tp+fsdp"
    # FSDP shards the "big other dim" of each weight over the data axes.
    # NOTE: on its own, GSPMD hoists the resulting all-gather of scan xs out of
    # the layer loop (O(all params) temp memory); ctx.constrain_layer_weights
    # pins the gather to the per-layer slice inside the loop (see launch/).
    fs = dax if fsdp else None
    heads_ok = _div(cfg.n_heads, TP)
    kv_ok = _div(cfg.n_kv_heads, TP)
    experts_ok = cfg.num_experts > 0 and _div(cfg.num_experts, TP)

    def attn_spec(f):
        if not heads_ok:                   # musicgen: replicate attn weights
            return {"wq": P(f, None), "wk": P(f, None),
                    "wv": P(f, None), "wo": P(None, f)}
        return {
            "wq": P(f, "model"),
            "wk": P(f, "model") if kv_ok else P(f, None),
            "wv": P(f, "model") if kv_ok else P(f, None),
            "wo": P("model", f),
        }

    def mlp_spec(f):
        return {"w_gate": P(f, "model"), "w_up": P(f, "model"),
                "w_down": P("model", f)}

    def moe_spec(f):
        if experts_ok:
            # EP over model + expert-hidden over data: both einsums are local
            # (contraction dims unsharded per tile) with one small activation
            # all-reduce — routed experts never need a weight gather, so this
            # 2D sharding serves storage AND compute (llama4: 96B experts ->
            # 0.75 GB bf16/device).
            s = {"router": P(None, None),
                 "w_gate": P("model", None, f),
                 "w_up": P("model", None, f),
                 "w_down": P("model", f, None)}
        else:                              # qwen2-moe (60e): hidden over model
            s = {"router": P(None, None),
                 "w_gate": P(None, f, "model"),
                 "w_up": P(None, f, "model"),
                 "w_down": P(None, "model", f)}
        if cfg.shared_expert_d_ff:
            s["shared"] = mlp_spec(f)
        return s

    def ssm_spec(f):
        return {
            "w_z": P(f, "model"), "w_x": P(f, "model"),
            "w_B": P(f, None), "w_C": P(f, None), "w_dt": P(f, "model"),
            "conv_x": P(None, "model"), "conv_B": P(None, None),
            "conv_C": P(None, None),
            "conv_bias_x": P("model"), "conv_bias_B": P(None),
            "conv_bias_C": P(None),
            "A_log": P("model"), "D": P("model"), "dt_bias": P("model"),
            "norm_scale": P("model"),
            "w_out": P("model", f),
        }

    def attn_layer(f):
        d = {"ln1": P(None), "ln2": P(None), "attn": attn_spec(f),
             ("moe" if cfg.num_experts else "mlp"):
                 (moe_spec(f) if cfg.num_experts else mlp_spec(f))}
        if cfg.post_norm:
            d["ln1_post"] = P(None)
            d["ln2_post"] = P(None)
        return d

    def stack(tree):   # layer-stacked params carry a leading L axis
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    specs: dict = {"final_norm": P(None)}
    if cfg.frontend != "audio_stub":
        specs["embed"] = P("model", fs)
    if not cfg.tie_embeddings or cfg.frontend == "audio_stub":
        specs["lm_head"] = P(fs, "model")
    if cfg.frontend != "none":
        specs["frontend"] = {"proj": P(None, None)}
    if cfg.block_pattern == "attn":
        specs["layers"] = stack(attn_layer(fs))
    else:
        specs["layers"] = stack({"ln": P(None), "ssm": ssm_spec(fs)})
        if cfg.block_pattern == "ssm+shared_attn":
            specs["shared_attn"] = attn_layer(fs)
    return specs


def compute_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...]) -> PyTree | None:
    """COMPUTE-time weight shardings for tp+fsdp archs: the gather-once-per-step
    ZeRO scheme. The train step casts master->bf16 and constrains every weight
    to these TP-only specs — ONE all-gather over the data axes per step,
    deliberately outside the layer loop (hoisting it is the point), and
    autodiff turns its transpose into the grad reduce-scatter. Routed-expert
    weights keep their 2D sharding (they never need gathering — see moe_spec).

    Returns None for pure-tp archs (compute == storage, no-op)."""
    import dataclasses
    if cfg.sharding_mode == "dp+zero1":
        # compute weights fully replicated: one all-gather per step, zero
        # per-layer collectives
        storage = _dp_zero1_specs(cfg, mesh_axes)
        return jax.tree.map(lambda s: P(*([None] * len(s))), storage,
                            is_leaf=lambda x: isinstance(x, P))
    if cfg.sharding_mode != "tp+fsdp":
        return None
    tp_cfg = dataclasses.replace(cfg, sharding_mode="tp")
    specs = param_specs(tp_cfg, mesh_axes)
    if cfg.num_experts and _div(cfg.num_experts, TP):
        moe2d = param_specs(cfg, mesh_axes)["layers"]["moe"]
        for kname in ("w_gate", "w_up", "w_down"):
            specs["layers"]["moe"][kname] = moe2d[kname]
    return specs


def opt_state_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...]) -> PyTree:
    """AdamState(step, mu, nu): moments shard like params."""
    from repro.optim.adam import AdamState
    ps = param_specs(cfg, mesh_axes)
    return AdamState(step=P(), mu=ps, nu=jax.tree.map(
        lambda s: s, ps, is_leaf=lambda x: isinstance(x, P)))


def batch_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...],
                global_batch: int) -> PyTree:
    if cfg.sharding_mode == "dp+zero1":
        allax = _all_axes(mesh_axes)
        n = 512 if "pod" in mesh_axes else 256
        bax = allax if _div(global_batch, n) else (
            _dax(mesh_axes) if _div(global_batch, n // 16) else None)
        out: dict = {}
        if cfg.frontend == "audio_stub":
            out["embeds"] = P(bax, None, None)
        elif cfg.frontend == "vlm_stub":
            out["embeds"] = P(bax, None, None)
            out["tokens"] = P(bax, None)
        else:
            out["tokens"] = P(bax, None)
        return out, bax
    dax = _dax(mesh_axes)
    ndev = 32 if "pod" in mesh_axes else 16
    bax = dax if _div(global_batch, ndev) else None
    out: dict = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = P(bax, None, None)
    elif cfg.frontend == "vlm_stub":
        out["embeds"] = P(bax, None, None)
        out["tokens"] = P(bax, None)
    else:
        out["tokens"] = P(bax, None)
    return out, bax


def decode_state_specs(cfg: ModelConfig, mesh_axes: tuple[str, ...],
                       global_batch: int) -> PyTree:
    _, bax = batch_specs(cfg, mesh_axes, global_batch)
    specs: dict = {"pos": P()}
    if cfg.block_pattern == "attn":
        specs["k"] = P(None, bax, "model", None, None)   # sequence-sharded cache
        specs["v"] = P(None, bax, "model", None, None)
    else:
        specs["conv"] = P(None, bax, None, "model")
        specs["ssd"] = P(None, bax, "model", None, None)
        if cfg.block_pattern == "ssm+shared_attn":
            specs["k"] = P(None, bax, "model", None, None)
            specs["v"] = P(None, bax, "model", None, None)
    return specs


def to_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
