"""Activation/weight sharding-hint context.

Model code stays mesh-agnostic; the launcher installs concrete NamedShardings
here around trace time. ``constrain_layer_weights`` pins the sharding of the
per-layer weight slice *inside* the layer loop — this is what keeps GSPMD from
hoisting the FSDP all-gather of the whole stacked parameter tensor out of the
scan (the difference between O(one layer) and O(all params) temp memory).
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax

_RULES: dict[str, Any] = {}


@contextlib.contextmanager
def sharding_rules(**rules):
    old = dict(_RULES)
    _RULES.update(rules)
    try:
        yield
    finally:
        _RULES.clear()
        _RULES.update(old)


def constrain_layer_weights(lp: Any) -> Any:
    """Apply the per-layer compute shardings (if installed) to a sliced layer
    params pytree."""
    sh = _RULES.get("layer_weights")
    if sh is None:
        return lp
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s) if s is not None else x,
        lp, sh)


def constrain(x: jax.Array, key: str) -> jax.Array:
    """Optional activation constraint hook (hillclimb lever)."""
    s = _RULES.get(key)
    return x if s is None else jax.lax.with_sharding_constraint(x, s)
