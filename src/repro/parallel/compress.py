"""Gradient compression: int8 quantization with stochastic rounding +
per-leaf scale, for the cross-pod gradient all-reduce.

At the 2x16x16 mesh the pod axis crosses the (slow) inter-pod links exactly
once per step with the full gradient; int8 compression cuts those bytes 4x
vs f32 (2x vs bf16) at <1e-3 relative quantization error (stochastic rounding
keeps the estimator unbiased; Adam's moments absorb the variance).

Usage in the step (opt-in):
    g8, scales = compress_tree(grads, key)
    g8 = psum-over-pod(g8) ... decompress_tree(g8, scales)
On a single-pod mesh this is a no-op path — see make_compressed_allreduce.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize(x: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int8 with stochastic rounding. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    y = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale


def compress_tree(tree: PyTree, key: jax.Array) -> tuple[PyTree, PyTree]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    qs, ss = zip(*(quantize(x.astype(jnp.float32), k)
                   for x, k in zip(leaves, keys)))
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, ss))


def decompress_tree(qtree: PyTree, stree: PyTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(lambda q, s: dequantize(q, s, dtype), qtree, stree)


def compressed_pod_mean(grads: PyTree, key: jax.Array, axis: str = "pod") -> PyTree:
    """Cross-pod gradient mean with int8 payload (for use inside shard_map):
    quantize -> psum int32 -> dequantize/mean. Scales are psum-maxed first so
    every pod quantizes on the same grid (exact mean of quantized values)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    n = jax.lax.psum(1, axis)
    out = []
    for x, k in zip(leaves, keys):
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)) / 127.0 + 1e-30, axis)
        noise = jax.random.uniform(k, x.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(x / scale + noise), -127, 127).astype(jnp.int8)
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        out.append((s.astype(jnp.float32) * scale / n).astype(x.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
