"""Roofline-term extraction from compiled XLA artifacts.

  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
  memory term     = HLO_bytes / HBM_bw                 (per chip)
  collective term = collective_bytes / link_bw         (per chip)

``cost_analysis()`` on a partitioned module reports per-device FLOPs/bytes.
Collective bytes are NOT in cost_analysis: we parse ``compiled.as_text()``
(post-SPMD HLO, where the collectives exist) and sum the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
**weighted by loop trip counts** — the layer scan wraps per-layer collectives in
a `while`, so a naive flat sum undercounts by n_layers. Trip counts are
recovered from the `constant(N)` in each while's condition computation
(heuristic, exact for lax.scan/fori_loop lowerings).
"""
from __future__ import annotations

import dataclasses
import re

from repro.models.config import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?: \([^)]*\))? \([^)]*\)\s*->", re.M)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: dict[str, int]
    n_ops: int


@dataclasses.dataclass
class HloCosts:
    """Trip-aware FLOPs / bytes: XLA's cost_analysis counts a while body ONCE
    regardless of trip count, so scanned-layer models under-report by ~n_layers.
    This walker multiplies per-computation costs by loop trip counts (same
    machinery as the collective counter)."""
    flops: float
    bytes_accessed: float


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith((" ", "\t")) and ("->" in line) and ("{" in line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else None
            if cur is not None:
                comps[cur] = []
            continue
        if cur is not None and stripped and stripped != "}":
            comps[cur].append(stripped)
        if stripped == "}":
            cur = None
    return comps


def _find_entry(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def _trip_count(comps: dict[str, list[str]], cond_comp: str) -> int:
    """Trip count of a lax.scan/fori while: resolve the constant operand of
    the condition's compare instruction (falling back to the max small
    constant in the condition)."""
    lines = comps.get(cond_comp, ())
    consts: dict[str, int] = {}
    for line in lines:
        m = re.match(r"\s*(?:ROOT )?%([\w\.\-]+) = \S+ constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in lines:
        if " compare(" in line:
            ops = re.findall(r"%([\w\.\-]+)", line.split("compare(", 1)[1])
            for o in ops[:2]:
                if o in consts:
                    return max(1, consts[o])
    small = [v for v in consts.values() if v <= 1 << 20]
    return max(small) if small else 1


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = _find_entry(hlo)

    # while instruction: condition=%c, body=%b
    while_re = re.compile(
        r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
    call_re = re.compile(r"(?:call|fusion)\(.*?\)(?:.*?)(?:to_apply|calls)=%?([\w\.\-]+)")
    cond_re = re.compile(r"conditional\(")
    branch_re = re.compile(r"(?:branch_computations=\{([^}]*)\}|"
                           r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))")
    const_re = re.compile(r"constant\((\d+)\)")

    def trip_count(cond_comp: str) -> int:
        return _trip_count(comps, cond_comp)

    by_kind: dict[str, int] = {k: 0 for k in COLLECTIVES}
    n_ops = 0

    # "%name = SHAPE op(args...)" — SHAPE may be a tuple "(f32[..], ...)"
    inst_re = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([\w\-\.]+)\(")

    def walk(comp: str, mult: int, seen: tuple = ()) -> int:
        nonlocal n_ops
        if comp in seen:   # defensive: HLO computations are acyclic
            return 0
        total = 0
        for line in comps.get(comp, ()):
            m = inst_re.search(line)
            if m:
                shape_text, op = m.group(1), m.group(2)
                kind = next((k for k in COLLECTIVES if op.startswith(k)), None)
                # async pairs (-start/-done) would double count; skip -done
                if kind and not op.startswith(kind + "-done"):
                    b = _shape_bytes(shape_text) * mult
                    by_kind[kind] += b
                    total += b
                    n_ops += mult
            m = while_re.search(line)
            if m:
                cond, bodyc = m.group(1), m.group(2)
                t = trip_count(cond)
                total += walk(bodyc, mult * t, seen + (comp,))
                continue
            m = branch_re.search(line)
            if m:
                branches = ([s.strip().lstrip("%") for s in m.group(1).split(",")]
                            if m.group(1) else [m.group(2), m.group(3)])
                # conditional: count the max-cost branch (scan/cond lowering)
                total += max((walk(b, mult, seen + (comp,)) for b in branches),
                             default=0)
                continue
            m = call_re.search(line)
            if m and any(k in line for k in ("call(",)):
                total += walk(m.group(1), mult, seen + (comp,))
        return total

    total = walk(entry, 1) if entry else 0
    return CollectiveStats(total_bytes=total, by_kind=by_kind, n_ops=n_ops)


_DEF_RE = re.compile(r"^(?:ROOT )?%([\w\.\-]+) = ((?:\([^)]*\)|\S+)) ([\w\-\.]+)\(")
_PARAM_HDR_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],\{\}]+))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def hlo_costs(hlo: str) -> HloCosts:
    """Trip-aware per-device FLOPs and HBM bytes from post-SPMD HLO.

    FLOPs: every ``dot`` costs 2 * prod(output) * prod(contracting dims of the
    lhs); convolutions and elementwise ops are ignored (dots dominate).
    Bytes: every non-trivial instruction reads its array operands and writes
    its output once (fusions are walked into, so their internals do not
    double-count; the fusion's own operands/outputs are skipped then)."""
    comps = _split_computations(hlo)
    entry = _find_entry(hlo)
    while_re = re.compile(
        r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
    call_re = re.compile(r"(?:to_apply|calls|body)=%?([\w\.\-]+)")
    branch_re = re.compile(r"(?:branch_computations=\{([^}]*)\}|"
                           r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))")
    const_re = re.compile(r"constant\((\d+)\)")

    # symbol tables: computation -> var name -> shape text
    tables: dict[str, dict[str, str]] = {}
    hdr_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^=]*\))?\s*\((.*)\)\s*->", )
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith((" ", "\t")) and "->" in line and "{" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else None
            if cur is not None:
                tables[cur] = {}
                # header params: "name: shape, name: shape"
                inner = stripped[stripped.find("(") + 1:stripped.rfind(") ->")]
                for pm in _PARAM_HDR_RE.finditer(inner):
                    tables[cur][pm.group(1)] = pm.group(2)
            continue
        if cur is None or not stripped or stripped == "}":
            if stripped == "}":
                cur = None
            continue
        dm = _DEF_RE.match(stripped)
        if dm:
            tables[cur][dm.group(1)] = dm.group(2)

    def _dims(shape_text: str) -> list[int]:
        m = _SHAPE_RE.search(shape_text)
        if not m or not m.group(2):
            return []
        return [int(d) for d in m.group(2).split(",") if d]

    def trip_count(cond_comp: str) -> int:
        return _trip_count(comps, cond_comp)

    def walk(comp: str, mult: float, seen: tuple = ()) -> tuple[float, float]:
        if comp in seen:
            return 0.0, 0.0
        fl = by = 0.0
        table = tables.get(comp, {})
        for line in comps.get(comp, ()):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            out_shape, op = dm.group(2), dm.group(3)
            wm = while_re.search(line)
            if wm:
                t = trip_count(wm.group(1))
                f2, b2 = walk(wm.group(2), mult * t, seen + (comp,))
                fl, by = fl + f2, by + b2
                continue
            bm = branch_re.search(line)
            if bm and "conditional(" in line:
                branches = ([s.strip().lstrip("%") for s in bm.group(1).split(",")]
                            if bm.group(1) else [bm.group(2), bm.group(3)])
                subs = [walk(b, mult, seen + (comp,)) for b in branches]
                if subs:
                    f2, b2 = max(subs)
                    fl, by = fl + f2, by + b2
                continue
            if op == "fusion":
                cm = call_re.search(line)
                if cm:
                    f2, b2 = walk(cm.group(1), mult, seen + (comp,))
                    fl += f2
                # fusion IO bytes: operands + output
                ob = _shape_bytes(out_shape)
                args = line[line.find("fusion(") + 7:line.find(")", line.find("fusion("))]
                ib = sum(_shape_bytes(table.get(a, "")) for a in
                         _OPERAND_RE.findall(args))
                by += (ob + ib) * mult
                continue
            if op.startswith("dot"):
                args = line[line.find("(") + 1:]
                names = _OPERAND_RE.findall(args)[:1]
                lhs_shape = table.get(names[0], "") if names else ""
                cdims = _CONTRACT_RE.search(line)
                contraction = 1
                ld = _dims(lhs_shape)
                if cdims and ld:
                    for ci in (int(x) for x in cdims.group(1).split(",") if x):
                        if ci < len(ld):
                            contraction *= ld[ci]
                out_elems = 1
                for d in _dims(out_shape):
                    out_elems *= d
                fl += 2.0 * out_elems * contraction * mult
                ob = _shape_bytes(out_shape)
                ib = sum(_shape_bytes(table.get(a, ""))
                         for a in _OPERAND_RE.findall(args)[:2])
                by += (ob + ib) * mult
                continue
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all"):
                continue
            # generic op: output + operand bytes
            ob = _shape_bytes(out_shape)
            args = line[line.find("(") + 1:line.find(")", line.find("("))] \
                if "(" in line else ""
            ib = sum(_shape_bytes(table.get(a, ""))
                     for a in _OPERAND_RE.findall(args))
            by += (ob + ib) * mult
        return fl, by

    fl, by = walk(entry, 1.0) if entry else (0.0, 0.0)
    return HloCosts(flops=fl, bytes_accessed=by)


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device
    hbm_bytes: float           # per-device
    coll_bytes: float          # per-device
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    peak_bytes: float          # per-device HBM high-water mark

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str | None = None) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    # trip-aware costs (XLA's cost_analysis counts while bodies once;
    # scanned-layer programs under-report by ~n_layers without this)
    costs = hlo_costs(text)
    flops = costs.flops
    hbm = costs.bytes_accessed
    tc = flops / PEAK_FLOPS_BF16
    tm = hbm / HBM_BW
    tx = coll.total_bytes / ICI_BW
    terms = {"compute": tc, "memory": tm, "collective": tx}
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=float(coll.total_bytes),
                    t_compute=tc, t_memory=tm, t_collective=tx,
                    bottleneck=max(terms, key=terms.get), peak_bytes=float(peak))
