"""Analytic per-device HBM model for each (arch × shape × mesh) cell.

Why this exists: the dry-run compiles on the CPU backend, and XLA:CPU has no
native bf16 — every bf16 arithmetic op is legalized to f32 with converts, so
``memory_analysis()`` reports f32-sized copies of bf16 buffers (stash,
activations, collectives). The measured number is kept as an *upper bound*;
this model gives the TPU-native expectation from first principles:

  train: master params (f32, storage-sharded) + Adam moments (2x) +
         grads (f32, storage-sharded) + bf16 compute copies (TP-sharded) +
         remat stash (ceil(L/G) x B_dev*S*D bf16) + per-group working set +
         chunked-CE logits + batch
  serve: bf16 params (TP-sharded) + caches (sharded per decode specs) +
         activation working set
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any


def _axis_sizes(mesh_axes: tuple[str, ...]) -> dict[str, int]:
    return {"pod": 2, "data": 16, "model": 16} if "pod" in mesh_axes else \
        {"data": 16, "model": 16}


def _shard_fraction(spec, sizes: dict[str, int]) -> float:
    denom = 1
    for part in spec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            denom *= sizes.get(ax, 1)
    return 1.0 / denom


def sharded_bytes(shapes: PyTree, specs: PyTree, sizes: dict[str, int],
                  itemsize: int | None = None) -> float:
    total = 0.0
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for arr, spec in zip(flat_s, flat_p):
        isz = itemsize if itemsize is not None else np.dtype(arr.dtype).itemsize
        total += math.prod(arr.shape) * isz * _shard_fraction(spec, sizes)
    return total


def pallas_tile_bytes(n_vec: int, pop_block: int, dim_pad: int, *,
                      n_row: int = 0, n_bcast: int = 0, itemsize: int = 4,
                      double_buffered: bool = True) -> int:
    """VMEM working set of one Pallas grid step of a fused optimizer kernel.

    ``n_vec`` counts the ``(pop_block, dim_pad)`` population tiles live in
    VMEM (inputs + outputs), ``n_row`` the ``(pop_block,)`` per-row operands
    (fitness, jrand, thresholds), ``n_bcast`` the ``(dim_pad,)`` broadcast
    rows (shift vector, global best). With ``double_buffered=True`` the
    row-blocked operands are counted twice — Mosaic prefetches grid step
    ``i+1`` while ``i`` computes — which is the feasibility bound the kernel
    autotuner checks against the VMEM budget.
    """
    vec = n_vec * pop_block * dim_pad + n_row * pop_block
    fixed = n_bcast * dim_pad
    mult = 2 if double_buffered else 1
    return (mult * vec + fixed) * itemsize


def analytic_memory(cfg: ModelConfig, kind: str, mesh_axes: tuple[str, ...],
                    B: int, S: int, params_shape: PyTree, p_specs: PyTree,
                    c_specs: PyTree | None, state_shape: PyTree = None,
                    state_specs: PyTree = None) -> dict[str, float]:
    sizes = _axis_sizes(mesh_axes)
    dax = sizes["data"] * sizes.get("pod", 1)
    b_dev = max(1, B // dax) if B % dax == 0 else B
    D = cfg.d_model
    G = cfg.remat_group if cfg.n_layers % cfg.remat_group == 0 else 1

    out: dict[str, float] = {}
    if kind == "train":
        master = sharded_bytes(params_shape, p_specs, sizes, itemsize=4)
        out["master_params"] = master
        out["adam_moments"] = 2 * master
        out["grads"] = master
        comp_specs = c_specs if c_specs is not None else p_specs
        out["bf16_compute_copies"] = sharded_bytes(params_shape, comp_specs,
                                                   sizes, itemsize=2)
        if cfg.block_pattern == "ssm+shared_attn":
            n_entries = cfg.n_layers // cfg.shared_attn_every + 1
        else:
            n_entries = math.ceil(cfg.n_layers / G)
        out["remat_stash"] = n_entries * b_dev * S * D * 2
        # transient working set during a group's backward recompute: the
        # scheduler frees layer intermediates as it goes — ~2 layers live
        # (4 full-width residual/cotangent streams + widest hidden each)
        ff_shard = max(cfg.d_ff, cfg.expert_ff, cfg.n_heads * cfg.hd) / sizes["model"]
        out["working_set"] = (min(G, 2) * b_dev * S * (4 * D + 2 * ff_shard) * 2)
        n_chunks = cfg.ce_chunks if S % max(cfg.ce_chunks, 1) == 0 else 1
        out["ce_logits"] = 2 * b_dev * (S // n_chunks) * (cfg.padded_vocab / sizes["model"]) * 4
        out["batch"] = 2 * b_dev * S * 4
    else:
        comp_specs = c_specs if c_specs is not None else p_specs
        out["bf16_params"] = sharded_bytes(params_shape, comp_specs, sizes,
                                           itemsize=2)
        if state_shape is not None:
            out["caches"] = sharded_bytes(state_shape, state_specs, sizes)
        width = 4 * D + 2 * max(cfg.d_ff, cfg.n_heads * cfg.hd) / sizes["model"]
        s_eff = S if kind == "prefill" else 1
        out["working_set"] = b_dev * s_eff * width * 2
        if kind == "prefill":
            out["attn_chunk"] = (b_dev * cfg.n_heads / sizes["model"]
                                 * S * cfg.attn_kv_block * 4)
        out["logits"] = b_dev * (cfg.padded_vocab / sizes["model"]) * 4 * (
            1 if kind == "decode" else 1)
    out["total"] = sum(out.values())
    return out
