"""DGABH — island-model Generalized Adaptive Basin Hopping (popt4jlib.BH, after [2]).

Each walker: perturb (ChromosomePerturberIntf -> Gaussian kick), descend with a
short stochastic local search (shrinking-step (1+1) probes), then Metropolis-accept
the new basin. Islands exchange walkers through the engine's starvation/ring
policies exactly like DGA.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    n_ls: int = 5,              # local-search probes per hop
    perturb_frac: float = 0.25, # basin-hop kick size
    ls_frac: float = 0.05,      # local-search initial step
    ls_shrink: float = 0.6,
    T: float = 1.0,             # Metropolis temperature between basins
) -> MetaHeuristic:
    """Basin-Hopping per-island policy (kick + local probe + Metropolis)."""
    lo, hi = f.lo, f.hi
    kick = perturb_frac * (hi - lo)
    step0 = ls_frac * (hi - lo)

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {"pop": x, "fit": fit, "best_arg": x[i], "best_val": fit[i]}

    def local_search(y: Array, fy: Array, key: Array):
        def body(c, carry):
            y, fy = carry
            k = jax.random.fold_in(key, c)
            step = step0 * (ls_shrink ** c)
            y2 = clip_box(y + step * jax.random.normal(k, y.shape), lo, hi)
            fy2 = evaluator(y2)
            imp = fy2 < fy
            return jnp.where(imp[:, None], y2, y), jnp.where(imp, fy2, fy)

        return jax.lax.fori_loop(0, n_ls, body, (y, fy))

    def gen(state: State, key: Array) -> State:
        x, fx = state["pop"], state["fit"]
        kk, kl, ka = jax.random.split(key, 3)
        y = clip_box(x + kick * jax.random.normal(kk, x.shape), lo, hi)
        fy = evaluator(y)
        y, fy = local_search(y, fy, kl)
        dF = fy - fx
        accept = (dF <= 0) | (jax.random.uniform(ka, fx.shape) < jnp.exp(-dF / T))
        x = jnp.where(accept[:, None], y, x)
        fx = jnp.where(accept, fy, fx)
        i = jnp.argmin(fx)
        better = fx[i] < state["best_val"]
        return {
            "pop": x, "fit": fx,
            "best_val": jnp.where(better, fx[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    return MetaHeuristic("bh", init, gen,
                         evals_per_gen=pop * (1 + n_ls), init_evals=pop)
