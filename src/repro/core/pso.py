"""DPSO — island-model Particle Swarm Optimization (popt4jlib.PS).

Velocity/position update with inertia w and cognitive/social factors f_p/f_g
(Fig.4 setup: w=0.6, f_p=f_g=1). The island's gbest is the SelectorIntf
"topology" (default: global-within-island); inter-island exchange uses the
engine's counter-clock-wise ring — the paper's DPSO default.

``fused=True`` routes the whole generation — velocity/position update,
evaluation, pbest selection — through the fused ``kernels.pso_step`` Pallas
kernel via the engine's ``step_override`` hook (same key discipline as the
XLA path, so both are bit-comparable on a fixed seed). Requires an objective
registered in ``kernels.registry``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function
from repro.kernels import registry as kreg
from repro.kernels.autotune import KernelConfig
from repro.kernels.pso_step import pso_step as _pso_step_kernel

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    w: float = 0.6,
    fp: float = 1.0,
    fg: float = 1.0,
    vmax_frac: float = 0.2,
    fused: bool = False,               # whole generation in one Pallas kernel
    interpret: bool | None = None,     # fused-kernel interpret mode; None = auto
    kernel_cfg: KernelConfig | None = None,
) -> MetaHeuristic:
    """Particle Swarm per-island policy (inertia w, cognitive fp, social fg)."""
    lo, hi = f.lo, f.hi
    vmax = vmax_frac * (hi - lo)

    def init(key: Array) -> State:
        kx, kv = jax.random.split(key)
        x = uniform_init(kx, pop, dim, lo, hi)
        v = vmax * (jax.random.uniform(kv, (pop, dim)) - 0.5)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit, "vel": v,
            # distinct buffers: the engine donates the state at round boundaries
            "pbest": jnp.copy(x), "pbest_f": jnp.copy(fit),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, v = state["pop"], state["vel"]
        k1, k2 = jax.random.split(key)
        r1 = jax.random.uniform(k1, x.shape)
        r2 = jax.random.uniform(k2, x.shape)
        v = w * v + fp * r1 * (state["pbest"] - x) + fg * r2 * (state["best_arg"] - x)
        v = jnp.clip(v, -vmax, vmax)
        x = clip_box(x + v, lo, hi)
        fit = evaluator(x)

        imp = fit < state["pbest_f"]
        pbest = jnp.where(imp[:, None], x, state["pbest"])
        pbest_f = jnp.where(imp, fit, state["pbest_f"])
        i = jnp.argmin(pbest_f)
        better = pbest_f[i] < state["best_val"]
        return {
            "pop": x, "fit": fit, "vel": v, "pbest": pbest, "pbest_f": pbest_f,
            "best_val": jnp.where(better, pbest_f[i], state["best_val"]),
            "best_arg": jnp.where(better, pbest[i], state["best_arg"]),
        }

    step_override = None
    if fused:
        spec = kreg.get_spec(f.name)   # KeyError if no kernel for this objective
        assert spec.fused_de, f.name

        def gen_fused(state: State, key: Array) -> State:
            # Same key discipline as gen, so fused and XLA paths draw
            # identical r1/r2 on a fixed seed.
            k1, k2 = jax.random.split(key)
            r1 = jax.random.uniform(k1, (pop, dim))
            r2 = jax.random.uniform(k2, (pop, dim))
            nx, nv, fit, npb, npbf = _pso_step_kernel(
                state["pop"], state["vel"], state["pbest"], state["pbest_f"],
                r1, r2, state["best_arg"], fn=spec.eval_tag, shift=f.shift,
                bias=f.bias, w=w, fp=fp, fg=fg, vmax=vmax, lo=lo, hi=hi,
                interpret=interpret, kernel_cfg=kernel_cfg,
            )
            i = jnp.argmin(npbf)
            better = npbf[i] < state["best_val"]
            return {
                "pop": nx, "fit": fit, "vel": nv, "pbest": npb, "pbest_f": npbf,
                "best_val": jnp.where(better, npbf[i], state["best_val"]),
                "best_arg": jnp.where(better, npb[i], state["best_arg"]),
            }

        step_override = gen_fused

    return MetaHeuristic("pso", init, gen, evals_per_gen=pop, init_evals=pop,
                         step_override=step_override)
