"""DPSO — island-model Particle Swarm Optimization (popt4jlib.PS).

Velocity/position update with inertia w and cognitive/social factors f_p/f_g
(Fig.4 setup: w=0.6, f_p=f_g=1). The island's gbest is the SelectorIntf
"topology" (default: global-within-island); inter-island exchange uses the
engine's counter-clock-wise ring — the paper's DPSO default.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    w: float = 0.6,
    fp: float = 1.0,
    fg: float = 1.0,
    vmax_frac: float = 0.2,
) -> MetaHeuristic:
    """Particle Swarm per-island policy (inertia w, cognitive fp, social fg)."""
    lo, hi = f.lo, f.hi
    vmax = vmax_frac * (hi - lo)

    def init(key: Array) -> State:
        kx, kv = jax.random.split(key)
        x = uniform_init(kx, pop, dim, lo, hi)
        v = vmax * (jax.random.uniform(kv, (pop, dim)) - 0.5)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit, "vel": v,
            # distinct buffers: the engine donates the state at round boundaries
            "pbest": jnp.copy(x), "pbest_f": jnp.copy(fit),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, v = state["pop"], state["vel"]
        k1, k2 = jax.random.split(key)
        r1 = jax.random.uniform(k1, x.shape)
        r2 = jax.random.uniform(k2, x.shape)
        v = w * v + fp * r1 * (state["pbest"] - x) + fg * r2 * (state["best_arg"] - x)
        v = jnp.clip(v, -vmax, vmax)
        x = clip_box(x + v, lo, hi)
        fit = evaluator(x)

        imp = fit < state["pbest_f"]
        pbest = jnp.where(imp[:, None], x, state["pbest"])
        pbest_f = jnp.where(imp, fit, state["pbest_f"])
        i = jnp.argmin(pbest_f)
        better = pbest_f[i] < state["best_val"]
        return {
            "pop": x, "fit": fit, "vel": v, "pbest": pbest, "pbest_f": pbest_f,
            "best_val": jnp.where(better, pbest_f[i], state["best_val"]),
            "best_arg": jnp.where(better, pbest[i], state["best_arg"]),
        }

    return MetaHeuristic("pso", init, gen, evals_per_gen=pop, init_evals=pop)
