"""DEA — multi-threaded Evolutionary Algorithm (popt4jlib.EA, after Michalewicz [4]).

A (mu + lambda) evolution strategy with Gaussian mutation and a multiplicative
1/5th-success-rule step-size adaptation — the classical EA the paper benchmarks.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    lam: int | None = None,
    sigma0_frac: float = 0.3,
) -> MetaHeuristic:
    """(mu+lambda) Evolutionary Algorithm per-island policy."""
    lo, hi = f.lo, f.hi
    lam = lam if lam is not None else pop

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit,
            "sigma": jnp.asarray(sigma0_frac * (hi - lo), jnp.float32),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, fit, sigma = state["pop"], state["fit"], state["sigma"]
        kp, km = jax.random.split(key)
        parents = jax.random.randint(kp, (lam,), 0, pop)
        child = clip_box(x[parents] + sigma * jax.random.normal(km, (lam, dim)), lo, hi)
        cfit = evaluator(child)

        # (mu + lambda) selection
        allx = jnp.concatenate([x, child], axis=0)
        allf = jnp.concatenate([fit, cfit], axis=0)
        keep = jnp.argsort(allf)[:pop]
        x, fit = allx[keep], allf[keep]

        # 1/5th success rule on the offspring
        succ = jnp.mean((cfit < jnp.median(fit)).astype(jnp.float32))
        sigma = jnp.clip(sigma * jnp.where(succ > 0.2, 1.05, 0.95),
                         1e-8 * (hi - lo), (hi - lo))
        i = jnp.argmin(fit)
        better = fit[i] < state["best_val"]
        return {
            "pop": x, "fit": fit, "sigma": sigma,
            "best_val": jnp.where(better, fit[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    return MetaHeuristic("ea", init, gen, evals_per_gen=lam, init_evals=pop)
