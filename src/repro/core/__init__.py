"""popt4jax core — the paper's contribution as composable JAX modules."""
from repro.core import bh, de, ea, fa, ga, mc, pso, sa  # noqa: F401
from repro.core import portfolio  # noqa: F401
from repro.core.api import (  # noqa: F401
    ObserverHub, OptimizeResult, Optimizer, OptRequest, OptResponse)
from repro.core.executor import ExecutorConfig, make_batch_evaluator  # noqa: F401
from repro.core.islands import (  # noqa: F401
    AsyncSchedule, BucketStepper, IslandConfig, IslandOptimizer,
    MetaHeuristic)
from repro.core.mesh import MeshConfig  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    explore_then_polish, explore_then_polish_many)
from repro.core.portfolio import AuxSlot, PolicySpec, Portfolio  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    AbandonRun, SchedulerOverloaded, ShapeBucketScheduler, UnknownJob)

ALGORITHMS = {
    "de": de.make,
    "ga": ga.make,
    "pso": pso.make,
    "sa": sa.make,
    "fa": fa.make,
    "ea": ea.make,
    "bh": bh.make,
    "mc": mc.make,
}
