"""Unified-state policy registry — heterogeneous algorithm portfolios for the
island engine (DESIGN.md §10).

popt4jlib's headline architectural claim is that ``OptimizerIntf`` lets
*different* meta-heuristics cooperate on one problem: the paper's Fig.4 runs
DGA, DDE, DPSO, DSA, DFA and DGABH side by side because no single method
dominates across functions. The engine reproduces that scenario *inside* the
compiled round scan: every registered policy declares its auxiliary state
slots (PSO velocity, SA temperature, GA ages, ...), the slots are padded into
one common pytree schema shared by all eight algorithms, and the per-island
generation step dispatches through ``lax.switch`` over the portfolio's
policies — so a mixed DE+PSO+SA island set runs as ONE jitted ``lax.scan``,
composing with ring/starvation migration, incumbent sharing, the hybrid
polish cadence and ``shard_map`` island sharding.

Schema (the *unified state*, per island):

    pop (P, D)  fit (P,)  best_arg (D,)  best_val ()      — common, every policy
    alive (P,) bool                                       — common liveness mask
                                                            (GA aging; all-True
                                                            for other policies)
    aux_vec (NV, P, D)  aux_ind (NP, P)  aux_scl (NS,)    — declared slots,
                                                            zero-padded to the
                                                            registry-wide maxima

``NV``/``NP``/``NS`` are maxima over the whole registry, so every portfolio —
and every branch of the ``lax.switch`` — shares one pytree structure.

Migration carries position + fitness only. When an island adopts a migrant,
the destination policy's aux slots *re-initialize* per the slot's declared
``adopt`` rule (``zero`` | ``pos`` | ``fit`` | ``keep``): a PSO island zeroes
the adopted particle's velocity and restarts its personal best at the
migrant's position; a GA island resets the age and revives the slot's
``alive`` bit. Per-island scalars (SA temperature, EA sigma, FA alpha) are
never touched by adoption.

``algo_id`` values are frozen — they identify policies across processes and
in serialized requests, so NEVER renumber an existing entry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bh, de, ea, fa, ga, mc, pso, sa
from repro.core.islands import AlgoMaker, MetaHeuristic, State
from repro.functions.benchmarks import Function

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AuxSlot:
    """One declared auxiliary state slot of a policy.

    ``kind`` places it in the unified schema: ``vec`` is per-individual
    ``(P, D)``, ``ind`` is per-individual scalar ``(P,)``, ``scl`` is one
    per-island scalar. ``adopt`` is the migration re-init rule applied to the
    slot's adopted rows (``zero`` | ``pos`` = copy the migrant's position |
    ``fit`` = copy the migrant's fitness | ``keep``); scalars are never
    re-initialized (adoption is per-individual).
    """

    name: str
    kind: str          # "vec" | "ind" | "scl"
    adopt: str = "keep"


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Registry entry: a meta-heuristic plus its unified-schema declaration.

    ``algo_id`` is the policy's stable wire identity (frozen forever);
    ``maker`` is the per-island factory (``de.make``-style); ``slots`` the
    aux slots its native state carries beyond the common pop/fit/best keys;
    ``needs_alive`` marks policies whose native state owns the ``alive``
    liveness mask (GA aging) rather than inheriting the all-True common one.
    """

    name: str
    algo_id: int
    maker: AlgoMaker
    slots: tuple[AuxSlot, ...] = ()
    needs_alive: bool = False


REGISTRY: dict[str, PolicySpec] = {}


def register(spec: PolicySpec) -> None:
    """Add a policy to the registry; name and algo_id must both be unused."""
    if any(s.kind not in ("vec", "ind", "scl") for s in spec.slots):
        raise ValueError(f"{spec.name}: unknown slot kind")
    if spec.name in REGISTRY:
        raise ValueError(f"policy {spec.name!r} already registered")
    if any(p.algo_id == spec.algo_id for p in REGISTRY.values()):
        raise ValueError(f"algo_id {spec.algo_id} already taken")
    REGISTRY[spec.name] = spec


# The eight policies of the paper's Fig.4 portfolio. algo_ids are frozen.
register(PolicySpec("de", 0, de.make))
register(PolicySpec("ga", 1, ga.make, slots=(
    AuxSlot("age", "ind", adopt="zero"),        # migrants arrive newborn
    AuxSlot("age_limit", "ind", adopt="keep"),  # slot keeps its drawn limit
), needs_alive=True))
register(PolicySpec("pso", 2, pso.make, slots=(
    AuxSlot("vel", "vec", adopt="zero"),        # adopted particle starts at rest
    AuxSlot("pbest", "vec", adopt="pos"),       # personal best restarts at the
    AuxSlot("pbest_f", "ind", adopt="fit"),     # migrant's position/fitness
)))
register(PolicySpec("sa", 3, sa.make, slots=(AuxSlot("t", "scl"),)))
register(PolicySpec("ea", 4, ea.make, slots=(AuxSlot("sigma", "scl"),)))
register(PolicySpec("fa", 5, fa.make, slots=(AuxSlot("alpha", "scl"),)))
register(PolicySpec("bh", 6, bh.make))
register(PolicySpec("mc", 7, mc.make))


def schema() -> tuple[int, int, int]:
    """(NV, NP, NS) — aux slot counts of the unified schema: per-kind maxima
    over the whole registry, so every portfolio shares one pytree structure."""
    nv = np_ = ns = 0
    for spec in REGISTRY.values():
        nv = max(nv, sum(1 for s in spec.slots if s.kind == "vec"))
        np_ = max(np_, sum(1 for s in spec.slots if s.kind == "ind"))
        ns = max(ns, sum(1 for s in spec.slots if s.kind == "scl"))
    return nv, np_, ns


def expand(portfolio: tuple[str, ...], n_islands: int) -> tuple[str, ...]:
    """Per-island policy names from a portfolio spec: used as-is when its
    length equals ``n_islands``, cycled round-robin when shorter (so
    ``("de", "pso", "sa")`` over 6 islands interleaves the three policies —
    ring neighbours run different algorithms). A spec LONGER than the island
    count is rejected: silently dropping requested policies would run a
    different portfolio than the one submitted."""
    if not portfolio:
        raise ValueError("empty portfolio")
    unknown = [n for n in portfolio if n not in REGISTRY]
    if unknown:
        raise ValueError(
            f"unknown portfolio policies {unknown}; registered: "
            f"{sorted(REGISTRY)}")
    if len(portfolio) > n_islands:
        raise ValueError(
            f"portfolio names {len(portfolio)} policies but there are only "
            f"{n_islands} islands — raise n_islands or drop policies")
    if len(portfolio) == n_islands:
        return tuple(portfolio)
    return tuple(portfolio[i % len(portfolio)] for i in range(n_islands))


class UnifiedPolicy:
    """One policy instance adapted to the unified state schema.

    Wraps the policy's native ``MetaHeuristic`` (dict state with its own
    keys) in pack/unpack shims so ``init``/``gen`` consume and produce the
    common schema — the pytree every ``lax.switch`` branch must share. The
    wrapped arithmetic and key discipline are untouched, which is what makes
    a homogeneous portfolio bit-identical to the plain engine.
    """

    def __init__(self, spec: PolicySpec, algo: MetaHeuristic,
                 pop: int, dim: int) -> None:
        self.spec = spec
        self.algo = algo
        self.pop = pop
        self.dim = dim
        self._nv, self._np, self._ns = schema()

    # -- schema shims ------------------------------------------------------

    def _pack(self, d: State, base: State | None = None) -> State:
        """Native policy state -> unified state. Aux slots the policy does
        not declare are zero-padded on every pack — nothing ever writes an
        island's undeclared slots, so re-zeroing is both correct and free of
        a carried dependency; ``base`` only supplies the common ``alive``
        mask for policies that do not own one."""
        P, D = self.pop, self.dim
        zv = jnp.zeros((P, D), jnp.float32)
        zp = jnp.zeros((P,), jnp.float32)
        vecs = [d[s.name] for s in self.spec.slots if s.kind == "vec"]
        inds = [d[s.name].astype(jnp.float32)
                for s in self.spec.slots if s.kind == "ind"]
        scls = [jnp.asarray(d[s.name], jnp.float32)
                for s in self.spec.slots if s.kind == "scl"]
        vecs += [zv] * (self._nv - len(vecs))
        inds += [zp] * (self._np - len(inds))
        scls += [jnp.zeros((), jnp.float32)] * (self._ns - len(scls))
        if self.spec.needs_alive:
            alive = d["alive"]
        else:
            alive = (base["alive"] if base is not None
                     else jnp.ones((P,), bool))
        return {
            "pop": d["pop"], "fit": d["fit"], "alive": alive,
            "best_arg": d["best_arg"], "best_val": d["best_val"],
            "aux_vec": jnp.stack(vecs) if self._nv else jnp.zeros((0, P, D)),
            "aux_ind": jnp.stack(inds) if self._np else jnp.zeros((0, P)),
            "aux_scl": jnp.stack(scls) if self._ns else jnp.zeros((0,)),
        }

    def _unpack(self, u: State) -> State:
        """Unified state -> exactly the native keys the wrapped policy's
        ``gen`` expects (its output dicts list their keys explicitly, so
        extra keys would be silently dropped — hence the exact set)."""
        d = {"pop": u["pop"], "fit": u["fit"],
             "best_arg": u["best_arg"], "best_val": u["best_val"]}
        if self.spec.needs_alive:
            d["alive"] = u["alive"]
        vi = pi = si = 0
        for s in self.spec.slots:
            if s.kind == "vec":
                d[s.name] = u["aux_vec"][vi]
                vi += 1
            elif s.kind == "ind":
                d[s.name] = u["aux_ind"][pi]
                pi += 1
            else:
                d[s.name] = u["aux_scl"][si]
                si += 1
        return d

    # -- unified interface -------------------------------------------------

    def init(self, key: Array) -> State:
        """Unified-schema single-island init (wraps the native init)."""
        return self._pack(self.algo.init(key))

    def gen(self, u: State, key: Array) -> State:
        """Unified-schema generation step — a ``lax.switch`` branch body."""
        step = (self.algo.step_override if self.algo.step_override is not None
                else self.algo.gen)
        return self._pack(step(self._unpack(u), key), base=u)

    def adopt(self, u: State, mask: Array) -> State:
        """Re-initialize aux slots of adopted migrants (DESIGN.md §10).

        ``mask (P,)`` marks slots whose pop/fit changed in this round's
        migration. Every policy revives adopted slots (``alive |= mask``);
        declared slots apply their ``adopt`` rule. Runs as a ``lax.switch``
        branch, so it returns the full unified state.
        """
        av, ap = u["aux_vec"], u["aux_ind"]
        vi = pi = 0
        for s in self.spec.slots:
            if s.kind == "vec":
                if s.adopt == "zero":
                    av = av.at[vi].set(jnp.where(mask[:, None], 0.0, av[vi]))
                elif s.adopt == "pos":
                    av = av.at[vi].set(jnp.where(mask[:, None], u["pop"], av[vi]))
                vi += 1
            elif s.kind == "ind":
                if s.adopt == "zero":
                    ap = ap.at[pi].set(jnp.where(mask, 0.0, ap[pi]))
                elif s.adopt == "fit":
                    ap = ap.at[pi].set(jnp.where(mask, u["fit"], ap[pi]))
                pi += 1
        return {**u, "alive": u["alive"] | mask, "aux_vec": av, "aux_ind": ap}


def adopt_native(name: str, state: State, mask: Array) -> State:
    """Apply a registered policy's migrant adopt rules to its NATIVE state
    dict — the plain (``algo_maker``) engine's analogue of
    :meth:`UnifiedPolicy.adopt`, so homogeneous portfolios and the plain
    engine share one adoption semantic (DESIGN.md §10): revive + age-reset
    for ga, velocity/pbest re-init for pso, no-op for slot-less policies.
    Unregistered custom policies fall back to the alive-revive alone.
    """
    out = dict(state)
    if "alive" in out:
        out["alive"] = out["alive"] | mask
    spec = REGISTRY.get(name)
    if spec is None:
        return out
    for s in spec.slots:
        if s.name not in out:
            continue
        if s.kind == "vec":
            if s.adopt == "zero":
                out[s.name] = jnp.where(mask[:, None], 0.0, out[s.name])
            elif s.adopt == "pos":
                out[s.name] = jnp.where(mask[:, None], out["pop"], out[s.name])
        elif s.kind == "ind":
            if s.adopt == "zero":
                out[s.name] = jnp.where(mask, 0.0, out[s.name])
            elif s.adopt == "fit":
                out[s.name] = jnp.where(mask, out["fit"], out[s.name])
    return out


def has_adopt_state(name: str) -> bool:
    """Whether a policy carries per-individual state that migration adoption
    must touch — decides if the plain engine computes the adopted mask."""
    spec = REGISTRY.get(name)
    return spec is not None and (
        spec.needs_alive or any(s.kind in ("vec", "ind") for s in spec.slots))


class Portfolio:
    """A built per-island policy assignment: the engine-facing object.

    ``names`` holds one policy name per island; ``policies`` one
    :class:`UnifiedPolicy` per *distinct* policy (the ``lax.switch`` branch
    table, in order of first appearance); ``branch_of`` maps island ->
    branch index. All stacked entry points take an optional ``branch``
    override so the sharded engine can pass each shard's local block of the
    (static, replicated) table.

    With a single distinct policy the switch is skipped entirely and the
    branch body is dispatched directly — the homogeneous portfolio therefore
    compiles to the same per-island program as the plain engine, which is
    what the bit-identity contract (DESIGN.md §10) rests on.
    """

    def __init__(self, names: tuple[str, ...],
                 policies: list[UnifiedPolicy]) -> None:
        self.names = names
        self.policies = policies
        order = [p.spec.name for p in policies]
        self.branch_of = np.asarray([order.index(n) for n in names],
                                    dtype=np.int32)
        self.algo_ids = tuple(REGISTRY[n].algo_id for n in names)
        # Islands whose policy owns the alive mask (ga aging); the engine's
        # migration pass uses isfinite(fit) for the rest, matching the plain
        # engine's alive=None default (DESIGN.md §10).
        self.owns_alive = np.asarray(
            [REGISTRY[n].needs_alive for n in names])

    @property
    def n_branches(self) -> int:
        """Distinct policies in the portfolio (the switch branch count)."""
        return len(self.policies)

    @property
    def per_gen_total(self) -> int:
        """Function evaluations one generation costs across all islands —
        the heterogeneous analogue of ``evals_per_gen * n_islands``."""
        return sum(self.policies[b].algo.evals_per_gen for b in self.branch_of)

    @property
    def init_total(self) -> int:
        """Function evaluations initialization costs across all islands."""
        return sum(self.policies[b].algo.init_evals for b in self.branch_of)

    def _branches(self, branch: Array | None) -> Array:
        return jnp.asarray(self.branch_of) if branch is None else branch

    def init_stacked(self, keys: Array, branch: Array | None = None) -> State:
        """Island-stacked unified init: one key row per island, dispatched
        through ``lax.switch`` (direct call when homogeneous)."""
        if self.n_branches == 1:
            return jax.vmap(self.policies[0].init)(keys)
        inits = [p.init for p in self.policies]
        return jax.vmap(
            lambda k, b: jax.lax.switch(b, inits, k))(keys, self._branches(branch))

    def step_stacked(self, state: State, keys: Array,
                     branch: Array | None = None) -> State:
        """One generation for every island: per-island ``lax.switch`` over
        the branch table — the heterogeneous ``vmap(gen)``."""
        if self.n_branches == 1:
            return jax.vmap(self.policies[0].gen)(state, keys)
        gens = [p.gen for p in self.policies]
        return jax.vmap(
            lambda s, k, b: jax.lax.switch(b, gens, s, k))(
                state, keys, self._branches(branch))

    def adopt_stacked(self, state: State, mask: Array,
                      branch: Array | None = None) -> State:
        """Apply each island's policy-specific migrant aux re-init
        (:meth:`UnifiedPolicy.adopt`) after a migration exchange."""
        if self.n_branches == 1:
            return jax.vmap(self.policies[0].adopt)(state, mask)
        adopts = [p.adopt for p in self.policies]
        return jax.vmap(
            lambda s, m, b: jax.lax.switch(b, adopts, s, m))(
                state, mask, self._branches(branch))


def build_portfolio(
    names: tuple[str, ...],
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    params: dict[str, Any] | None = None,
    kernel_cfg: Any = None,
) -> Portfolio:
    """Materialize a per-island policy assignment into a :class:`Portfolio`.

    ``names`` is the expanded (length ``n_islands``) assignment from
    :func:`expand`. ``params`` maps policy name -> extra maker kwargs (a
    dict, or the pair-tuple form JSONL requests freeze it to); entries for
    policies outside the portfolio are rejected so typos fail loudly.
    ``kernel_cfg`` (a ``kernels.autotune.KernelConfig``, threaded from
    ``ExecutorConfig.kernel`` by the engine) is injected into every maker
    that declares the parameter, so fused ``lax.switch`` branches tile
    consistently; explicit per-policy params win.
    """
    from repro.core.islands import _accepts_kernel_cfg
    params = dict(params or {})
    distinct = list(dict.fromkeys(names))
    extra = set(params) - set(distinct)
    if extra:
        raise ValueError(
            f"params for policies not in the portfolio: {sorted(extra)} "
            f"(portfolio: {distinct})")
    policies = []
    for n in distinct:
        kw = params.get(n, {})
        if not isinstance(kw, dict):   # OptRequest freezes dicts to pairs
            kw = dict(kw)
        spec = REGISTRY[n]
        if (kernel_cfg is not None and "kernel_cfg" not in kw
                and _accepts_kernel_cfg(spec.maker)):
            kw["kernel_cfg"] = kernel_cfg
        algo = spec.maker(f=f, evaluator=evaluator, pop=pop, dim=dim, **kw)
        policies.append(UnifiedPolicy(spec, algo, pop, dim))
    return Portfolio(tuple(names), policies)
