"""Optimizer coupling — the paper's Observer pattern and the Fig.4 X/FCG combos.

popt4jlib couples a meta-heuristic (SubjectIntf) with a local-search optimizer
(ObserverIntf): each new incumbent triggers a descent to the nearest saddle
point. Fig.4's "GA/FCG (50-50 function evaluations)" splits the budget equally
between the global phase and the FCG refinement phase; we reproduce exactly
that protocol (refinement starts from the global phase's incumbent).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.api import ObserverHub, OptimizeResult
from repro.core.islands import IslandConfig, IslandOptimizer
from repro.functions.benchmarks import Function
from repro.optim import descent

Array = jax.Array


def with_fcg_postprocessing(
    meta: IslandOptimizer,
    f: Function,
    key: Array,
    dim: int,
    total_evals: int,
    split: float = 0.5,
    dcfg: descent.DescentConfig | None = None,
) -> OptimizeResult:
    """Fig.4 combo: meta-heuristic for ``split`` of the budget, FCG the rest."""
    k1, k2 = jax.random.split(key)
    meta_cfg = dataclasses.replace(meta.cfg, max_evals=int(total_evals * split))
    global_phase = IslandOptimizer(meta.algo_maker, meta_cfg, meta.params, meta.mesh)
    res = global_phase.minimize(f, k1)

    budget_left = total_evals - res.n_evals
    dcfg = dcfg or descent.DescentConfig()
    dcfg = dataclasses.replace(dcfg, max_evals=budget_left)
    # FCG refinement seeded at the meta-heuristic's incumbent (Observer hand-off).
    refined = _fcg_from(f, res.arg, k2, dim, dcfg)
    if refined.value < res.value:
        return OptimizeResult(arg=refined.arg, value=refined.value,
                              n_evals=res.n_evals + refined.n_evals)
    return OptimizeResult(arg=res.arg, value=res.value,
                          n_evals=res.n_evals + refined.n_evals)


def _fcg_from(f: Function, x0: Array, key: Array, dim: int,
              cfg: descent.DescentConfig) -> OptimizeResult:
    """FCG with a fixed starting point (restarts remain random)."""
    from repro.optim.numgrad import make_grad
    grad_fn = make_grad(f.fn, cfg.grad_mode)

    def run(x0, key):
        fx0 = f.fn(x0)
        g0, ge = grad_fn(x0)
        c0 = descent._Carry(x0, fx0, g0, -g0, jnp.sum(g0 * g0),
                            jnp.asarray(ge + 1), x0, fx0, key)

        def cond(c):
            return c.evals < cfg.max_evals

        def body(c):
            x1, f1, ls = descent._armijo(f.fn, c.x, c.fx, c.g, c.d, cfg)
            g1, ge2 = grad_fn(x1)
            gg1 = jnp.sum(g1 * g1)
            b = gg1 / jnp.maximum(c.gg_prev, 1e-30)
            d1 = -g1 + b * c.d
            d1 = jnp.where(jnp.sum(d1 * g1) < 0, d1, -g1)
            done = (jnp.sqrt(gg1) < cfg.gtol) | (f1 >= c.fx - 1e-15)
            key, rk = jax.random.split(c.key)
            xr = jax.random.uniform(rk, x1.shape, minval=f.lo, maxval=f.hi)
            fr = f.fn(xr)
            gr, ger = grad_fn(xr)
            x2 = jnp.where(done, xr, x1)
            f2 = jnp.where(done, fr, f1)
            g2 = jnp.where(done, gr, g1)
            d2 = jnp.where(done, -gr, d1)
            gg2 = jnp.where(done, jnp.sum(gr * gr), gg1)
            evals = c.evals + ls + ge2 + jnp.where(done, ger + 1, 0)
            best = f2 < c.best_f
            return descent._Carry(x2, f2, g2, d2, gg2, evals,
                                  jnp.where(best, x2, c.best_x),
                                  jnp.where(best, f2, c.best_f), key)

        return jax.lax.while_loop(cond, body, c0)

    if cfg.max_evals <= 0:
        return OptimizeResult(arg=x0, value=float(f.fn(x0)), n_evals=1)
    out = jax.jit(run)(x0, key)
    return OptimizeResult(arg=out.best_x, value=float(out.best_f),
                          n_evals=int(out.evals))


def observed_local_search(f: Function, dim: int, hub: ObserverHub,
                          budget_per_refine: int = 2000) -> None:
    """Register an FCG observer on the hub: every incumbent notification is
    refined to the nearest saddle point (the paper's AVD/FCG ObserverIntf)."""

    def refine(arg: Array, value: float):
        cfg = descent.DescentConfig(max_evals=budget_per_refine)
        res = _fcg_from(f, arg, jax.random.PRNGKey(0), dim, cfg)
        return (res.arg, res.value) if res.value < value else None

    hub.register(refine)
