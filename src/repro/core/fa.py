"""DFA — island-model Firefly Algorithm (popt4jlib.PS.FA, after Yang [7]).

Fig.4 setup: beta=1, delta=0.97 (randomness decay), gamma=200, L=1/sqrt(gamma).
Every firefly moves toward each brighter one with attraction beta*exp(-gamma r^2)
plus a decaying random walk; O(P^2 D) per generation (P is small: 50 in the paper).

Eval accounting: the pairwise attraction reads only the *cached* fitness of
the previous generation — none of the O(P^2) interactions queries the
objective — so a generation consumes exactly ``pop`` evaluations (one batch
evaluator call on the moved swarm) for ANY population size, not just the
paper's P=50 default. ``evals_per_gen=pop`` below is that invariant, and
``tests/test_metaheuristics.py::test_evals_per_gen_parity`` counts actual
evaluator rows at a non-default ``pop`` to enforce it for all eight
registered policies.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    beta0: float = 1.0,
    gamma: float = 200.0,
    delta: float = 0.97,
    alpha0: float = 1.0,
) -> MetaHeuristic:
    """Firefly Algorithm per-island policy (attraction beta0, absorption gamma)."""
    lo, hi = f.lo, f.hi
    L = 1.0 / jnp.sqrt(gamma)

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit, "alpha": jnp.asarray(alpha0, jnp.float32),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, fit, alpha = state["pop"], state["fit"], state["alpha"]
        diff = x[None, :, :] - x[:, None, :]            # (i, j, D): x_j - x_i
        r2 = jnp.sum(diff * diff, axis=-1)              # (i, j)
        brighter = (fit[None, :] < fit[:, None]).astype(x.dtype)
        attract = beta0 * jnp.exp(-gamma * r2) * brighter
        move = jnp.einsum("ij,ijd->id", attract, diff)
        noise = alpha * L * (jax.random.uniform(key, x.shape) - 0.5)
        x = clip_box(x + move + noise, lo, hi)
        fit = evaluator(x)   # the generation's ONLY objective queries: P rows
        i = jnp.argmin(fit)
        better = fit[i] < state["best_val"]
        return {
            "pop": x, "fit": fit, "alpha": alpha * delta,
            "best_val": jnp.where(better, fit[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    return MetaHeuristic("fa", init, gen, evals_per_gen=pop, init_evals=pop)
