"""DGA — island-model Genetic Algorithm (popt4jlib.GA).

Paper features reproduced: elitist roulette-wheel selection on per-generation
fitness; 1-pt crossover + per-allele mutation (XOverOpIntf / MutationOpIntf ->
pure functions); the aging mechanism (each individual draws a Gaussian age limit
at birth and dies past it, so island populations vary over time); starvation
migration is handled by the engine via the ``alive`` mask. Fixed-capacity
population arrays + liveness masks replace Java's growing/shrinking ArrayLists
(static shapes for XLA); a dead slot carries +inf fitness and is never selected.
The island best is exempt from aging (elitism).

``fused=True`` routes the offspring wave — crossover, mutation, evaluation,
slot-placement selection — through the fused ``kernels.ga_step`` Pallas
kernel via the engine's ``step_override`` hook; aging, roulette sampling and
the worst-slot argsort stay in XLA (cross-population ops). Same key
discipline as the XLA path, so both are bit-comparable on a fixed seed.
Requires an objective registered in ``kernels.registry``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function
from repro.kernels import registry as kreg
from repro.kernels.autotune import KernelConfig
from repro.kernels.ga_step import ga_step as _ga_step_kernel

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    pc: float = 0.7,            # 1-pt crossover probability (Fig.4 setup)
    pm: float = 0.1,            # per-allele mutation probability (Fig.4 setup)
    mut_scale: float = 0.1,     # Gaussian mutation sigma, fraction of box width
    n_offspring: int | None = None,
    age_mean: float = 1e9,      # aging disabled by default (Fig.4 single-island runs)
    age_sd: float = 0.0,
    fused: bool = False,        # offspring wave in one Pallas kernel
    interpret: bool | None = None,
    kernel_cfg: KernelConfig | None = None,
) -> MetaHeuristic:
    """Genetic Algorithm per-island policy (1-pt crossover, Gaussian mutation,
    optional aging — the paper's DGA island member)."""
    lo, hi = f.lo, f.hi
    n_off = n_offspring if n_offspring is not None else max(1, pop // 4)
    sigma_m = mut_scale * (hi - lo)

    def draw_limits(key: Array, n: int) -> Array:
        return age_mean + age_sd * jax.random.normal(key, (n,))

    def init(key: Array) -> State:
        kp, kl = jax.random.split(key)
        p = uniform_init(kp, pop, dim, lo, hi)
        fit = evaluator(p)
        i = jnp.argmin(fit)
        return {
            "pop": p, "fit": fit,
            "age": jnp.zeros((pop,), jnp.float32),
            "age_limit": draw_limits(kl, pop).astype(jnp.float32),
            "alive": jnp.ones((pop,), bool),
            "best_arg": p[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        p, fit = state["pop"], state["fit"]
        age, limit, alive = state["age"] + 1.0, state["age_limit"], state["alive"]
        ksel, kcut, kco, kmm, kmn, klim = jax.random.split(key, 6)

        # --- aging: individuals past their Gaussian-drawn limit die (elitism:
        # the island's best individual never ages out).
        elite = jnp.argmin(jnp.where(alive, fit, jnp.inf))
        died = alive & (age > limit) & (jnp.arange(pop) != elite)
        alive = alive & ~died
        fit = jnp.where(alive, fit, jnp.inf)

        # --- roulette-wheel selection among the living (minimization -> weight
        # by distance from the worst finite fitness).
        finite = jnp.where(jnp.isfinite(fit), fit, -jnp.inf)
        worst = jnp.max(finite)
        w = jnp.where(alive, jnp.maximum(worst - fit, 0.0) + 1e-9, 0.0)
        logw = jnp.log(w + 1e-30)
        parents = jax.random.categorical(ksel, logw, shape=(2, n_off))
        p1, p2 = p[parents[0]], p[parents[1]]

        # --- 1-pt crossover with probability pc
        cut = jax.random.randint(kcut, (n_off, 1), 1, dim)
        do_co = (jax.random.uniform(kco, (n_off, 1)) < pc)
        mask = jnp.arange(dim)[None, :] < cut
        child = jnp.where(do_co & mask | ~do_co, p1, p2)

        # --- per-allele Gaussian mutation with probability pm
        mmask = jax.random.uniform(kmm, (n_off, dim)) < pm
        child = child + jnp.where(mmask, sigma_m * jax.random.normal(kmn, (n_off, dim)), 0.0)
        child = clip_box(child, lo, hi)
        cfit = evaluator(child)

        # --- placement: offspring land in the worst slots (dead slots first,
        # since they carry +inf fitness); only if they improve that slot.
        order = jnp.argsort(fit)[::-1][:n_off]       # worst n_off slots
        slot_f = fit[order]
        take = cfit < slot_f
        p = p.at[order].set(jnp.where(take[:, None], child, p[order]))
        fit = fit.at[order].set(jnp.where(take, cfit, slot_f))
        age = age.at[order].set(jnp.where(take, 0.0, age[order]))
        limit = limit.at[order].set(
            jnp.where(take, draw_limits(klim, n_off).astype(jnp.float32), limit[order]))
        alive = alive.at[order].set(alive[order] | take)

        i = jnp.argmin(fit)
        better = fit[i] < state["best_val"]
        return {
            "pop": p, "fit": fit, "age": age, "age_limit": limit, "alive": alive,
            "best_val": jnp.where(better, fit[i], state["best_val"]),
            "best_arg": jnp.where(better, p[i], state["best_arg"]),
        }

    step_override = None
    if fused:
        spec = kreg.get_spec(f.name)   # KeyError if no kernel for this objective
        assert spec.fused_de, f.name

        def gen_fused(state: State, key: Array) -> State:
            # Identical pre-kernel phases (aging, roulette, draws) and key
            # discipline as gen; the (n_off, D) crossover/mutation/eval/
            # placement middle runs in the fused kernel.
            p, fit = state["pop"], state["fit"]
            age, limit, alive = state["age"] + 1.0, state["age_limit"], state["alive"]
            ksel, kcut, kco, kmm, kmn, klim = jax.random.split(key, 6)

            elite = jnp.argmin(jnp.where(alive, fit, jnp.inf))
            died = alive & (age > limit) & (jnp.arange(pop) != elite)
            alive = alive & ~died
            fit = jnp.where(alive, fit, jnp.inf)

            finite = jnp.where(jnp.isfinite(fit), fit, -jnp.inf)
            worst = jnp.max(finite)
            wgt = jnp.where(alive, jnp.maximum(worst - fit, 0.0) + 1e-9, 0.0)
            logw = jnp.log(wgt + 1e-30)
            parents = jax.random.categorical(ksel, logw, shape=(2, n_off))
            p1, p2 = p[parents[0]], p[parents[1]]

            cut = jax.random.randint(kcut, (n_off, 1), 1, dim)
            co = jax.random.uniform(kco, (n_off, 1))
            um = jax.random.uniform(kmm, (n_off, dim))
            nz = jax.random.normal(kmn, (n_off, dim))

            order = jnp.argsort(fit)[::-1][:n_off]   # worst n_off slots
            nslot, nslot_f, take = _ga_step_kernel(
                p1, p2, p[order], fit[order], cut[:, 0], co[:, 0], um, nz,
                fn=spec.eval_tag, shift=f.shift, bias=f.bias, pc=pc, pm=pm,
                sigma_m=sigma_m, lo=lo, hi=hi,
                interpret=interpret, kernel_cfg=kernel_cfg,
            )
            p = p.at[order].set(nslot)
            fit = fit.at[order].set(nslot_f)
            age = age.at[order].set(jnp.where(take, 0.0, age[order]))
            limit = limit.at[order].set(
                jnp.where(take, draw_limits(klim, n_off).astype(jnp.float32),
                          limit[order]))
            alive = alive.at[order].set(alive[order] | take)

            i = jnp.argmin(fit)
            better = fit[i] < state["best_val"]
            return {
                "pop": p, "fit": fit, "age": age, "age_limit": limit,
                "alive": alive,
                "best_val": jnp.where(better, fit[i], state["best_val"]),
                "best_arg": jnp.where(better, p[i], state["best_arg"]),
            }

        step_override = gen_fused

    return MetaHeuristic("ga", init, gen, evals_per_gen=n_off, init_evals=pop,
                         step_override=step_override)
