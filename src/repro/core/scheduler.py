"""Shape-bucketed multi-job scheduler — popt4jlib ``PDBatchTaskExecutorSrv``
over the device-resident island engine (DESIGN.md §5, hardened in §12).

The Java server accepts batches of independent ``TaskObject``s from many
clients and farms them to a worker network. Here the "worker network" is one
compiled XLA program: concurrent :class:`~repro.core.api.OptRequest`s are
bucketed by compiled shape-class (``OptRequest.shape_class()`` — everything
but the seed), and each bucket is packed into a single jitted run by adding a
leading *jobs* axis over the engine state (``IslandOptimizer.minimize_many``).
``vmap`` over jobs composes with the per-island ``vmap`` and the executor's
``shard_map``, so N same-shaped jobs cost one dispatch instead of N — and one
compilation instead of N, because the per-bucket optimizer (and its evaluator,
via the executor cache) is reused across flushes.

Hybrid memetic requests (``OptRequest.polish != "none"``, DESIGN.md §6) bucket
separately from plain ones: the polish fields are part of the shape-class, so
a mixed hybrid/plain traffic stream can never collide two different compiled
programs into one bucket. Portfolio requests (``OptRequest.portfolio``,
DESIGN.md §10) follow the same rule — the per-island policy assignment is
compiled into the program's ``lax.switch`` branch table, so portfolio and
homogeneous jobs (or two different portfolios) never share a bucket either.

Service hardening (DESIGN.md §12) — the paper's §IV network-of-JVMs server
(``pdbtexec``) reimagined as POLO-style swappable execution policy:

  * ``workers > 0`` runs bucket flushes on a bounded worker-thread pool with
    **priority lanes** (highest submitted priority in a bucket wins) instead
    of blocking the caller;
  * eligible buckets execute through ``IslandOptimizer.bucket_stepper`` — the
    host-stepped, bit-identical sibling of ``minimize_many`` — so each run
    **streams per-round progress** into its jobs' :class:`OptResponse`s,
    honors **cooperative cancellation** at round boundaries (partial result
    returned), and **snapshots its engine state** through
    ``checkpoint/store.py`` on a cadence;
  * ``resume(dir)`` restores interrupted bucket runs after a crash/SIGKILL
    and finishes them **bit-identically** to an uninterrupted run (same
    round-key streams, restored state);
  * ``max_pending`` bounds the host-side queue — submissions over capacity
    are **load-shed** with :class:`SchedulerOverloaded` carrying a
    ``retry_after_ms`` hint.

POLO-style policy/execution separation: the algorithms never learn whether
they ran standalone, under the scheduler, sharded over a mesh, or stepped a
round at a time by a preemptible service worker.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import json
import os
import shutil
import threading
import time
import traceback
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.store import CheckpointStore
from repro.core.api import OptimizeResult, OptRequest, OptResponse
from repro.core.executor import ExecutorConfig
from repro.core.islands import IslandConfig, IslandOptimizer
from repro.core.mesh import MeshConfig
from repro.functions import get as get_function

BucketKey = tuple

FINAL_STATUSES = ("done", "error", "cancelled")


class SchedulerOverloaded(RuntimeError):
    """Load-shed signal: the scheduler's bounded pending queue is full.

    The service maps this to a structured ``{"error": "overloaded",
    "retry_after_ms": ...}`` reply instead of queueing without bound —
    clients back off and retry (DESIGN.md §12 backpressure)."""

    def __init__(self, retry_after_ms: int) -> None:
        super().__init__(f"pending queue full; retry in {retry_after_ms} ms")
        self.retry_after_ms = retry_after_ms


class UnknownJob(KeyError):
    """Lookup of a job id the scheduler does not hold (never submitted, or
    evicted by a fetch-once ``result``) — mapped by the service to a
    structured ``{"error": "unknown-id"}`` reply instead of a traceback."""


class AbandonRun(Exception):
    """Fault-injection escape hatch: raised from a ``fault_hook`` to make a
    worker abandon its bucket mid-run *without* finalizing jobs or cleaning
    up checkpoints — simulating a killed process so tests can exercise
    ``resume`` in-process (DESIGN.md §12)."""


@dataclasses.dataclass
class _Job:
    request: OptRequest
    response: OptResponse
    submitted_at: float  # host monotonic clock; drives deadline-based flush
    priority: int = 0              # higher runs first (service priority lanes)
    cancel_requested: bool = False # cooperative: honored at round boundaries
    preemptible: bool = False      # True while a host-stepped run owns the job
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def finished(self) -> bool:
        return self.response.status in FINAL_STATUSES


@dataclasses.dataclass
class _RunItem:
    """One dispatched bucket run: the job rows (in key order; ``None`` rows
    are jobs that finished before a resumed run was interrupted) plus an
    optional restored-state payload for resumed runs."""

    key: BucketKey
    rows: list
    resume: dict | None = None     # {"state", "start", "hist"}
    store_dir: str | None = None   # resumed runs keep their original dir


class ShapeBucketScheduler:
    """Accepts many concurrent OptRequests, runs each shape-class as one
    jobs-axis dispatch.

    Host-side lifecycle: ``submit`` queues a job into its bucket;
    ``flush``/``flush_bucket`` executes pending buckets (inline when
    ``workers == 0``, on the priority worker pool otherwise); ``poll``
    reports status + streamed progress without blocking; ``result`` forces
    the job's bucket to run and returns its :class:`OptResponse` envelope;
    ``cancel`` preempts cooperatively at the next round boundary;
    ``resume`` restores interrupted runs from a checkpoint directory.
    """

    def __init__(self, mesh: Mesh | None = None,
                 exec_cfg: ExecutorConfig = ExecutorConfig(),
                 max_cached_buckets: int = 64,
                 workers: int = 0,
                 max_pending: int = 0,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 8,
                 fault_hook: Callable[[BucketKey, int], None] | None = None) -> None:
        self.mesh = mesh
        self.exec_cfg = exec_cfg
        # shape-classes are client-controlled, so the compiled-program caches
        # are LRU-capped — a traffic mix wider than the cap recompiles instead
        # of growing host/device memory without bound
        self.max_cached_buckets = max_cached_buckets
        self.workers = workers
        self.max_pending = max_pending       # 0 = unbounded (no load-shed)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, checkpoint_every)
        self.fault_hook = fault_hook         # tests/benchmarks inject faults here
        self._pending: dict[BucketKey, list[_Job]] = {}
        self._jobs: dict[str, _Job] = {}
        self._optimizers: dict[BucketKey, IslandOptimizer] = {}
        self._functions: dict[tuple[str, int], Any] = {}
        self._ids = itertools.count()
        self.n_dispatches = 0   # bucket runs issued (perf accounting)
        self.n_jobs_run = 0
        self.n_shed = 0         # submissions load-shed by backpressure
        self.n_cancelled = 0
        self.n_resumed = 0      # jobs restored from checkpoints
        self.n_resume_failed = 0
        # Worker pool: a priority heap of _RunItems drained by daemon threads.
        self._mu = threading.RLock()
        self._cv = threading.Condition(self._mu)
        self._ready: list[tuple[int, int, _RunItem]] = []  # (-prio, seq, item)
        self._seq = itertools.count()
        self._shutdown = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"sched-worker-{i}")
            for i in range(workers)]
        for t in self._threads:
            t.start()

    # -- submission --------------------------------------------------------

    def submit(self, req: OptRequest, job_id: str | None = None,
               priority: int = 0) -> str:
        """Queue a job into its shape-class bucket; returns its job id.

        ``priority`` feeds the worker pool's lanes: when workers pick the
        next bucket, the one holding the highest-priority job wins (FIFO
        within a lane). Raises :class:`SchedulerOverloaded` when
        ``max_pending`` is set and the queue is full (load-shed)."""
        with self._mu:
            if self.max_pending and self._n_waiting() >= self.max_pending:
                self.n_shed += 1
                backlog = self._n_waiting() // max(1, self.workers or 1)
                raise SchedulerOverloaded(min(5000, 50 * (1 + backlog)))
            if job_id is None:
                job_id = f"job{next(self._ids)}"
                while job_id in self._jobs:  # skip ids a client claimed itself
                    job_id = f"job{next(self._ids)}"
            elif job_id in self._jobs:
                raise ValueError(f"duplicate job id {job_id!r}")
            job = _Job(req, OptResponse(job_id), time.monotonic(),
                       priority=priority)
            self._jobs[job_id] = job
            self._pending.setdefault(req.shape_class(), []).append(job)
            return job_id

    def _n_waiting(self) -> int:
        """Jobs queued but not yet running (pending buckets + ready heap) —
        the quantity ``max_pending`` bounds. Callers hold ``_mu``."""
        n = sum(len(v) for v in self._pending.values())
        for _, _, item in self._ready:
            n += sum(1 for j in item.rows if j is not None and not j.finished())
        return n

    # -- bucket plumbing ---------------------------------------------------

    def _lru_get(self, cache: dict, key):
        """Hit moves the entry to the MRU end (dicts keep insertion order)."""
        val = cache.pop(key, None)
        if val is not None:
            cache[key] = val
        return val

    def _lru_put(self, cache: dict, key, val) -> None:
        cache[key] = val
        while len(cache) > self.max_cached_buckets:
            cache.pop(next(iter(cache)))

    def _function(self, req: OptRequest):
        with self._mu:
            fk = (req.fn, req.dim)
            f = self._lru_get(self._functions, fk)
            if f is None:
                f = get_function(req.fn, req.dim)
                self._lru_put(self._functions, fk, f)
            return f

    def _optimizer(self, req: OptRequest) -> IslandOptimizer:
        with self._mu:
            key = req.shape_class()
            opt = self._lru_get(self._optimizers, key)
            if opt is None:
                from repro.core import ALGORITHMS  # late: core/__init__ imports us
                cfg = IslandConfig(
                    n_islands=req.n_islands, pop=req.pop, dim=req.dim,
                    sync_every=req.sync_every, migration=req.migration,
                    n_migrants=req.n_migrants, share_incumbent=req.share_incumbent,
                    max_evals=req.max_evals, polish=req.polish,
                    polish_every=req.polish_every, polish_topk=req.polish_topk,
                    polish_steps=req.polish_steps, portfolio=req.portfolio,
                    sync_policy=req.sync_policy,
                    max_staleness=req.max_staleness,
                )
                # Portfolio requests (DESIGN.md §10) run heterogeneous per-island
                # policies: `algo` is ignored and `params` maps policy name ->
                # kwargs (build_portfolio thaws the frozen pair-tuples).
                maker = None if req.portfolio else ALGORITHMS[req.algo]
                # Sharded requests (devices > 1, DESIGN.md §8) get their own
                # island mesh; MeshConfig.build raises inside flush_bucket's
                # fault isolation when the host lacks the devices, so one
                # impossible request cannot take the service down.
                mesh_cfg = (MeshConfig(devices=req.devices)
                            if req.devices > 1 else None)
                opt = IslandOptimizer(
                    maker, cfg, params=dict(req.params),
                    mesh=None if mesh_cfg is not None else self.mesh,
                    mesh_cfg=mesh_cfg,
                    exec_cfg=dataclasses.replace(self.exec_cfg, backend=req.backend),
                )
                self._lru_put(self._optimizers, key, opt)
            return opt

    def pending_buckets(self) -> list[tuple[BucketKey, int, float]]:
        """(key, n_jobs, oldest_submit_time) per non-empty bucket."""
        with self._mu:
            return [(k, len(js), js[0].submitted_at)  # FIFO: first is oldest
                    for k, js in self._pending.items()]

    def pending_count(self, key: BucketKey) -> int:
        """Queued jobs in one bucket — O(1), for the service's size trigger."""
        with self._mu:
            return len(self._pending.get(key, ()))

    # -- execution ---------------------------------------------------------

    def flush_bucket(self, key: BucketKey) -> list[str]:
        """Dispatch every pending job in one bucket as a single jobs-axis run.

        With ``workers == 0`` the run executes inline (the blocking baseline);
        otherwise it is enqueued on the priority worker pool and this returns
        immediately with the dispatched job ids."""
        with self._mu:
            jobs = self._pending.pop(key, [])
            if not jobs:
                return []
            item = _RunItem(key, jobs)
            if self.workers:
                prio = max(j.priority for j in jobs)
                heapq.heappush(self._ready, (-prio, next(self._seq), item))
                self._cv.notify()
                return [j.response.job_id for j in jobs]
        self._run_bucket(item)
        return [j.response.job_id for j in jobs]

    def flush(self) -> int:
        """Dispatch all pending buckets; returns the number of jobs moved."""
        n = 0
        for key, _, _ in self.pending_buckets():
            n += len(self.flush_bucket(key))
        return n

    def drain(self, timeout: float | None = None) -> bool:
        """Flush everything and wait for all known jobs to reach a final
        status; True if fully drained within ``timeout``."""
        self.flush()
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._mu:
            jobs = list(self._jobs.values())
        for j in jobs:
            left = None if deadline is None else deadline - time.monotonic()
            if left is not None and left <= 0:
                return False
            if not j.finished() and not j.done.wait(left):
                return False
        return True

    def close(self) -> None:
        """Stop the worker pool (idle workers exit; running buckets finish)."""
        with self._mu:
            self._shutdown = True
            self._cv.notify_all()

    def _worker(self) -> None:
        while True:
            with self._mu:
                while not self._ready and not self._shutdown:
                    self._cv.wait()
                if self._shutdown and not self._ready:
                    return
                _, _, item = heapq.heappop(self._ready)
            try:
                self._run_bucket(item)
            except AbandonRun:
                pass     # fault injection: leave jobs/checkpoints untouched
            except Exception:  # noqa: BLE001 — a worker must never die silently
                traceback.print_exc()

    # -- the bucket run ----------------------------------------------------

    def _finalize(self, job: _Job, status: str,
                  result: OptimizeResult | None = None,
                  error: str | None = None) -> None:
        resp = job.response
        resp.result, resp.error = result, error
        if result is not None:
            resp.best_val = result.value
            resp.evals_done = result.n_evals
        resp.status = status        # status last: readers see a complete record
        job.preemptible = False
        if status == "cancelled":
            self.n_cancelled += 1
        job.done.set()

    def _run_bucket(self, item: _RunItem) -> None:
        """Execute one dispatched bucket (worker-thread or inline body)."""
        key, rows = item.key, item.rows
        with self._mu:
            # cancellations that arrived while queued: finalize without running
            for j in list(rows):
                if j is not None and j.cancel_requested and not j.finished():
                    self._finalize(j, "cancelled")
            live = [j for j in rows
                    if j is not None and not j.finished()]
            if not live:
                return
            for j in live:
                j.response.status = "running"
        req0 = live[0].request
        try:
            opt = self._optimizer(req0)
            f = self._function(req0)
            try:
                stepper = opt.bucket_stepper(f)
            except ValueError:      # sharded/meshed engine: no host stepping
                stepper = None
            if stepper is None:
                self._run_resident(item, opt, f)
            else:
                self._run_stepped(item, stepper)
        except AbandonRun:
            raise
        except Exception as e:  # noqa: BLE001 — job-level fault isolation
            msg = f"{type(e).__name__}: {e}"
            traceback.print_exc()
            with self._mu:
                for j in rows:
                    if j is not None and not j.finished():
                        self._finalize(j, "error", error=msg)

    def _run_resident(self, item: _RunItem, opt: IslandOptimizer, f) -> None:
        """Device-resident fallback (sharded/meshed buckets): one opaque
        ``minimize_many`` dispatch — no streaming, no mid-run preemption.
        Warm-started buckets (``OptRequest.warm``, the federation hop) run
        per-job ``minimize`` calls instead: warm is value-keyed into the
        shape-class, so every row shares the same batch."""
        jobs = [j for j in item.rows if j is not None and not j.finished()]
        keys = jnp.stack([jax.random.PRNGKey(j.request.seed) for j in jobs])
        warm = jobs[0].request.warm
        if warm:
            results = [opt.minimize(f, k, warm=np.asarray(warm, np.float32))
                       for k in keys]
        else:
            results = opt.minimize_many(f, keys)
        with self._mu:
            self.n_dispatches += 1
            self.n_jobs_run += len(jobs)
            for j, res in zip(jobs, results):
                self._finalize(j, "done", result=res)

    def _run_store(self, item: _RunItem) -> CheckpointStore | None:
        """Per-run checkpoint store under ``checkpoint_dir`` — the directory
        name is a digest of the (id, request) rows, so a restarted server
        finds exactly the runs it was killed holding."""
        if item.store_dir is not None:     # resumed: keep the original dir so
            return CheckpointStore(item.store_dir, keep=2)  # no stale run leaks
        if self.checkpoint_dir is None:
            return None
        spec = [(j.response.job_id if j is not None else None,
                 dataclasses.asdict(j.request) if j is not None else None)
                for j in item.rows]
        digest = hashlib.sha256(
            json.dumps(spec, sort_keys=True, default=str).encode()).hexdigest()
        return CheckpointStore(
            os.path.join(self.checkpoint_dir, f"run_{digest[:12]}"), keep=2)

    def _run_stepped(self, item: _RunItem, stepper) -> None:
        """Host-stepped bucket run: stream progress, honor cancellation at
        round boundaries, checkpoint on the cadence (DESIGN.md §12). The
        trajectory is bit-identical to ``minimize_many`` on the same keys."""
        rows = item.rows
        keys = jnp.stack([
            jax.random.PRNGKey(j.request.seed if j is not None else 0)
            for j in rows])
        n_rounds, sync_every = stepper.n_rounds, stepper.cfg.sync_every
        if item.resume is None:
            state, round_keys = stepper.init(keys)
            start, hist = 0, []
            # Federation warm-start (OptRequest.warm, value-keyed into the
            # shape-class so the whole bucket shares one batch): adopt the
            # immigrants before round 0. Checkpoints snapshot post-injection
            # state, so resumed runs never re-inject.
            req0 = next(j.request for j in rows if j is not None)
            if req0.warm:
                state = stepper.inject(
                    state, np.asarray(req0.warm, np.float32))
        else:
            state = item.resume["state"]
            start = item.resume["start"]
            hist = list(item.resume["hist"])
            round_keys = stepper.round_keys(keys)
        store = self._run_store(item)
        live = {i for i, j in enumerate(rows)
                if j is not None and not j.finished()}

        with self._mu:
            self.n_dispatches += 1
            self.n_jobs_run += len(live)
        for i in live:
            rows[i].preemptible = True
            rows[i].response.n_rounds = n_rounds

        def partial_row(i: int, r_done: int, args, vals) -> OptimizeResult:
            h = (np.stack(hist, axis=1)[i] if hist
                 else np.zeros((0,), np.float32))
            return OptimizeResult(
                arg=np.asarray(args[i]), value=float(vals[i]),
                n_evals=stepper.evals_done(r_done),
                n_gens=r_done * sync_every, history=h)

        for r in range(start, n_rounds):
            state, vals = stepper.step(state, round_keys, r)
            vals_np = np.asarray(vals)
            hist.append(vals_np)
            r_done = r + 1
            for i in live:
                resp = rows[i].response
                resp.round = r_done
                resp.best_val = float(vals_np[i])
                resp.evals_done = stepper.evals_done(r_done)
            # cooperative preemption: cancelled jobs leave with the incumbent
            # they hold at this round boundary (partial result)
            cancels = [i for i in live if rows[i].cancel_requested]
            if cancels:
                args, bvals = stepper.best(state)
                args, bvals = np.asarray(args), np.asarray(bvals)
                with self._mu:
                    for i in cancels:
                        self._finalize(rows[i], "cancelled",
                                       result=partial_row(i, r_done, args, bvals))
                        live.discard(i)
            if not live:
                break
            if (store is not None and r_done % self.checkpoint_every == 0
                    and r_done < n_rounds):
                self._save_checkpoint(store, item, state, hist, r_done)
            if self.fault_hook is not None:
                self.fault_hook(item.key, r_done)

        if live:
            args, bvals = stepper.best(state)
            args, bvals = np.asarray(args), np.asarray(bvals)
            hist_arr = np.stack(hist, axis=1)
            with self._mu:
                for i in live:
                    res = OptimizeResult(
                        arg=args[i], value=float(bvals[i]),
                        n_evals=stepper.evals_done(n_rounds),
                        n_gens=n_rounds * sync_every, history=hist_arr[i])
                    self._finalize(rows[i], "done", result=res)
        if store is not None:       # run is over: its snapshots are garbage
            store.wait()
            shutil.rmtree(store.root, ignore_errors=True)

    def _save_checkpoint(self, store: CheckpointStore, item: _RunItem,
                         state, hist: list, r_done: int) -> None:
        """Snapshot the run: engine state + history as the pytree payload,
        round counter + per-row (id, request, priority, liveness) as the
        manifest extra ``resume`` rebuilds the run from."""
        tree = {"state": state,
                "history": np.stack(hist, axis=1).astype(np.float32)}
        extra = {"round": r_done, "jobs": [
            None if j is None or j.finished() else {
                "id": j.response.job_id, "priority": j.priority,
                "request": dataclasses.asdict(j.request)}
            for j in item.rows]}
        store.save(r_done, tree, extra=extra, blocking=False)

    # -- crash recovery ----------------------------------------------------

    def resume(self, root: str) -> dict[str, Any]:
        """Restore every interrupted bucket run under ``root`` and requeue it
        (inline when ``workers == 0``). Jobs come back under their original
        ids and finish **bit-identically** to an uninterrupted run — the
        restored state plus the re-derived round-key streams replay exactly
        the rounds the killed server never ran. A checkpoint that fails
        checksum validation is rejected cleanly: its jobs are registered in
        ``error`` status (``n_resume_failed`` counts them) and the server
        keeps serving. Returns a summary dict."""
        summary: dict[str, Any] = {"resumed": [], "failed": []}
        if not os.path.isdir(root):
            return summary
        for name in sorted(os.listdir(root)):
            run_dir = os.path.join(root, name)
            if not os.path.isdir(run_dir):
                continue
            store = CheckpointStore(run_dir, keep=2)
            if not store.list_steps():
                continue
            try:
                item = self._restore_run(store)
            except Exception as e:  # noqa: BLE001 — reject cleanly, keep serving
                self.n_resume_failed += 1
                summary["failed"].append({"dir": name, "error": str(e)})
                self._mark_resume_failed(store, name, e)
                continue
            ids = [j.response.job_id for j in item.rows if j is not None]
            self.n_resumed += len(ids)
            summary["resumed"].append({"dir": name, "jobs": ids,
                                       "round": item.resume["start"]})
            if self.workers:
                with self._mu:
                    prio = max((j.priority for j in item.rows
                                if j is not None), default=0)
                    heapq.heappush(self._ready, (-prio, next(self._seq), item))
                    self._cv.notify()
            else:
                self._run_bucket(item)
        return summary

    def _restore_run(self, store: CheckpointStore) -> _RunItem:
        """Rebuild one interrupted run: requests from the manifest, state
        shapes from a fresh stepper, leaves checksum-validated by the store."""
        step = store.latest_step()
        manifest = store.read_manifest(step)
        extra = manifest["extra"]
        specs = extra["jobs"]
        reqs = [None if s is None else OptRequest.from_dict(s["request"])
                for s in specs]
        req0 = next(r for r in reqs if r is not None)
        opt = self._optimizer(req0)
        f = self._function(req0)
        stepper = opt.bucket_stepper(f)
        keys = jnp.stack([
            jax.random.PRNGKey(r.seed if r is not None else 0) for r in reqs])
        like = {"state": stepper.state_shape(keys),
                "history": jax.ShapeDtypeStruct(
                    (len(reqs), extra["round"]), np.float32)}
        _, tree, _ = store.restore(like, step=step)
        hist_arr = np.asarray(tree["history"])
        rows: list = []
        with self._mu:
            for spec, req in zip(specs, reqs):
                if spec is None:
                    rows.append(None)
                    continue
                if spec["id"] in self._jobs:
                    raise ValueError(f"job id {spec['id']!r} already registered")
                job = _Job(req, OptResponse(spec["id"]), time.monotonic(),
                           priority=spec.get("priority", 0))
                self._jobs[spec["id"]] = job
                rows.append(job)
        return _RunItem(
            key=req0.shape_class(), rows=rows, store_dir=store.root,
            resume={"state": tree["state"], "start": extra["round"],
                    "hist": [hist_arr[:, i] for i in range(hist_arr.shape[1])]})

    def _mark_resume_failed(self, store: CheckpointStore, name: str,
                            err: Exception) -> None:
        """Register a rejected checkpoint's jobs (when the manifest is still
        readable) in ``error`` status so clients get a structured answer."""
        try:
            manifest = store.read_manifest(store.latest_step())
        except Exception:  # noqa: BLE001 — manifest unreadable: nothing to mark
            return
        with self._mu:
            for spec in manifest.get("extra", {}).get("jobs", []):
                if spec is None or spec["id"] in self._jobs:
                    continue
                job = _Job(OptRequest.from_dict(spec["request"]),
                           OptResponse(spec["id"]), time.monotonic())
                self._jobs[spec["id"]] = job
                self._finalize(job, "error",
                               error=f"checkpoint restore failed: {err}")

    # -- retrieval ---------------------------------------------------------

    def poll(self, job_id: str) -> OptResponse:
        """Non-blocking status + streamed-progress lookup; never triggers a
        bucket run. Raises :class:`UnknownJob` for unknown/evicted ids."""
        try:
            return self._jobs[job_id].response
        except KeyError:
            raise UnknownJob(job_id) from None

    def result(self, job_id: str, evict: bool = False,
               timeout: float | None = None) -> OptResponse:
        """Blocking fetch: dispatch the job's bucket if it has not run yet,
        then wait for a final status (pool mode waits on the job's event; the
        inline mode has already run it).

        ``evict=True`` drops the finished job's record (the Java server's
        fetch-once result semantics) — long-lived services use it so the job
        table does not grow without bound.
        """
        with self._mu:
            try:
                job = self._jobs[job_id]
            except KeyError:
                raise UnknownJob(job_id) from None
        if job.response.status == "queued":
            self.flush_bucket(job.request.shape_class())
        if self.workers:
            job.done.wait(timeout)
        with self._mu:
            if evict and job.finished():
                self._jobs.pop(job_id, None)
        return job.response

    def cancel(self, job_id: str) -> dict[str, Any]:
        """Cancel a job: queued jobs are withdrawn immediately; running
        host-stepped jobs are preempted cooperatively at the next round
        boundary and return a *partial* result. Returns a structured reply
        dict; raises :class:`UnknownJob` for unknown/evicted ids. A finished
        or non-preemptible job yields ``{"error": ...}`` instead of a
        traceback."""
        with self._mu:
            try:
                job = self._jobs[job_id]
            except KeyError:
                raise UnknownJob(job_id) from None
            status = job.response.status
            if status in FINAL_STATUSES:
                return {"id": job_id, "error": "already-finished",
                        "status": status}
            if status == "queued":
                job.cancel_requested = True
                bucket = self._pending.get(job.request.shape_class())
                if bucket is not None and job in bucket:
                    bucket.remove(job)     # withdrawn before dispatch
                    if not bucket:
                        del self._pending[job.request.shape_class()]
                    self._finalize(job, "cancelled")
                    return {"id": job_id, "status": "cancelled"}
                return {"id": job_id, "status": "cancelling"}
            if not job.preemptible:
                return {"id": job_id, "error": "not-cancellable",
                        "status": status}
            job.cancel_requested = True
            return {"id": job_id, "status": "cancelling"}

    # -- introspection -----------------------------------------------------

    def bucket_status(self) -> dict[str, dict[str, Any]]:
        """Per-bucket lifecycle counts + engine sync policy over the jobs the
        scheduler holds — the service's ``status`` op. Buckets are labeled
        ``fn|algo|dim=D|#hash`` (hash over the full shape-class); each entry
        is ``{"counts": {status: n}, "sync_policy": "barrier"|"async"}``."""
        out: dict[str, dict[str, Any]] = {}
        with self._mu:
            for job in self._jobs.values():
                req = job.request
                key = req.shape_class()
                h = hashlib.sha256(repr(key).encode()).hexdigest()[:8]
                algo = "portfolio" if req.portfolio else req.algo
                label = f"{req.fn}|{algo}|dim={req.dim}|#{h}"
                entry = out.setdefault(
                    label, {"counts": {}, "sync_policy": req.sync_policy})
                st = job.response.status
                entry["counts"][st] = entry["counts"].get(st, 0) + 1
        return out

    def queue_depth(self) -> int:
        """Dispatched buckets waiting in the worker-pool priority queue —
        backlog the pool has accepted but not yet started (the service's
        ``status`` op reports it alongside the buckets)."""
        with self._mu:
            return len(self._ready)

    def stats(self) -> dict[str, int]:
        """Queue/dispatch/hardening counters for the service's ``stats`` op."""
        with self._mu:
            return {
                "submitted": len(self._jobs),
                "pending": sum(len(v) for v in self._pending.values()),
                "buckets_pending": len(self._pending),
                "dispatches": self.n_dispatches,
                "jobs_run": self.n_jobs_run,
                "workers": self.workers,
                "shed": self.n_shed,
                "cancelled": self.n_cancelled,
                "resumed": self.n_resumed,
                "resume_failed": self.n_resume_failed,
            }
