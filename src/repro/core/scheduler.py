"""Shape-bucketed multi-job scheduler — popt4jlib ``PDBatchTaskExecutorSrv``
over the device-resident island engine (DESIGN.md §5).

The Java server accepts batches of independent ``TaskObject``s from many
clients and farms them to a worker network. Here the "worker network" is one
compiled XLA program: concurrent :class:`~repro.core.api.OptRequest`s are
bucketed by compiled shape-class (``OptRequest.shape_class()`` — everything
but the seed), and each bucket is packed into a single jitted run by adding a
leading *jobs* axis over the engine state (``IslandOptimizer.minimize_many``).
``vmap`` over jobs composes with the per-island ``vmap`` and the executor's
``shard_map``, so N same-shaped jobs cost one dispatch instead of N — and one
compilation instead of N, because the per-bucket optimizer (and its evaluator,
via the executor cache) is reused across flushes.

Hybrid memetic requests (``OptRequest.polish != "none"``, DESIGN.md §6) bucket
separately from plain ones: the polish fields are part of the shape-class, so
a mixed hybrid/plain traffic stream can never collide two different compiled
programs into one bucket. Portfolio requests (``OptRequest.portfolio``,
DESIGN.md §10) follow the same rule — the per-island policy assignment is
compiled into the program's ``lax.switch`` branch table, so portfolio and
homogeneous jobs (or two different portfolios) never share a bucket either.

POLO-style policy/execution separation: the algorithms never learn whether
they ran standalone, under the scheduler, or sharded over a mesh.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.api import OptRequest, OptResponse
from repro.core.executor import ExecutorConfig
from repro.core.islands import IslandConfig, IslandOptimizer
from repro.core.mesh import MeshConfig
from repro.functions import get as get_function

BucketKey = tuple


@dataclasses.dataclass
class _Job:
    request: OptRequest
    response: OptResponse
    submitted_at: float  # host monotonic clock; drives deadline-based flush


class ShapeBucketScheduler:
    """Accepts many concurrent OptRequests, runs each shape-class as one
    jobs-axis dispatch.

    Host-side lifecycle: ``submit`` queues a job into its bucket;
    ``flush``/``flush_bucket`` executes pending buckets; ``poll`` reports
    status without blocking; ``result`` forces the job's bucket to run and
    returns its :class:`OptimizeResult` envelope.
    """

    def __init__(self, mesh: Mesh | None = None,
                 exec_cfg: ExecutorConfig = ExecutorConfig(),
                 max_cached_buckets: int = 64) -> None:
        self.mesh = mesh
        self.exec_cfg = exec_cfg
        # shape-classes are client-controlled, so the compiled-program caches
        # are LRU-capped — a traffic mix wider than the cap recompiles instead
        # of growing host/device memory without bound
        self.max_cached_buckets = max_cached_buckets
        self._pending: dict[BucketKey, list[_Job]] = {}
        self._jobs: dict[str, _Job] = {}
        self._optimizers: dict[BucketKey, IslandOptimizer] = {}
        self._functions: dict[tuple[str, int], Any] = {}
        self._ids = itertools.count()
        self.n_dispatches = 0   # bucket runs issued (perf accounting)
        self.n_jobs_run = 0

    # -- submission --------------------------------------------------------

    def submit(self, req: OptRequest, job_id: str | None = None) -> str:
        """Queue a job into its shape-class bucket; returns its job id."""
        if job_id is None:
            job_id = f"job{next(self._ids)}"
            while job_id in self._jobs:    # skip ids a client claimed itself
                job_id = f"job{next(self._ids)}"
        elif job_id in self._jobs:
            raise ValueError(f"duplicate job id {job_id!r}")
        job = _Job(req, OptResponse(job_id), time.monotonic())
        self._jobs[job_id] = job
        self._pending.setdefault(req.shape_class(), []).append(job)
        return job_id

    # -- bucket plumbing ---------------------------------------------------

    def _lru_get(self, cache: dict, key):
        """Hit moves the entry to the MRU end (dicts keep insertion order)."""
        val = cache.pop(key, None)
        if val is not None:
            cache[key] = val
        return val

    def _lru_put(self, cache: dict, key, val) -> None:
        cache[key] = val
        while len(cache) > self.max_cached_buckets:
            cache.pop(next(iter(cache)))

    def _function(self, req: OptRequest):
        fk = (req.fn, req.dim)
        f = self._lru_get(self._functions, fk)
        if f is None:
            f = get_function(req.fn, req.dim)
            self._lru_put(self._functions, fk, f)
        return f

    def _optimizer(self, req: OptRequest) -> IslandOptimizer:
        key = req.shape_class()
        opt = self._lru_get(self._optimizers, key)
        if opt is None:
            from repro.core import ALGORITHMS  # late: core/__init__ imports us
            cfg = IslandConfig(
                n_islands=req.n_islands, pop=req.pop, dim=req.dim,
                sync_every=req.sync_every, migration=req.migration,
                n_migrants=req.n_migrants, share_incumbent=req.share_incumbent,
                max_evals=req.max_evals, polish=req.polish,
                polish_every=req.polish_every, polish_topk=req.polish_topk,
                polish_steps=req.polish_steps, portfolio=req.portfolio,
            )
            # Portfolio requests (DESIGN.md §10) run heterogeneous per-island
            # policies: `algo` is ignored and `params` maps policy name ->
            # kwargs (build_portfolio thaws the frozen pair-tuples).
            maker = None if req.portfolio else ALGORITHMS[req.algo]
            # Sharded requests (devices > 1, DESIGN.md §8) get their own
            # island mesh; MeshConfig.build raises inside flush_bucket's
            # fault isolation when the host lacks the devices, so one
            # impossible request cannot take the service down.
            mesh_cfg = (MeshConfig(devices=req.devices)
                        if req.devices > 1 else None)
            opt = IslandOptimizer(
                maker, cfg, params=dict(req.params),
                mesh=None if mesh_cfg is not None else self.mesh,
                mesh_cfg=mesh_cfg,
                exec_cfg=dataclasses.replace(self.exec_cfg, backend=req.backend),
            )
            self._lru_put(self._optimizers, key, opt)
        return opt

    def pending_buckets(self) -> list[tuple[BucketKey, int, float]]:
        """(key, n_jobs, oldest_submit_time) per non-empty bucket."""
        return [(k, len(js), js[0].submitted_at)  # FIFO: first is oldest
                for k, js in self._pending.items()]

    def pending_count(self, key: BucketKey) -> int:
        """Queued jobs in one bucket — O(1), for the service's size trigger."""
        return len(self._pending.get(key, ()))

    # -- execution ---------------------------------------------------------

    def flush_bucket(self, key: BucketKey) -> list[str]:
        """Run every pending job in one bucket as a single jobs-axis dispatch."""
        jobs = self._pending.pop(key, [])
        if not jobs:
            return []
        for j in jobs:
            j.response.status = "running"
        req0 = jobs[0].request
        try:
            opt = self._optimizer(req0)
            f = self._function(req0)
            keys = jnp.stack(
                [jax.random.PRNGKey(j.request.seed) for j in jobs])
            results = opt.minimize_many(f, keys)
        except Exception as e:  # noqa: BLE001 — job-level fault isolation
            msg = f"{type(e).__name__}: {e}"
            traceback.print_exc()
            for j in jobs:
                j.response.status = "error"
                j.response.error = msg
            return [j.response.job_id for j in jobs]
        self.n_dispatches += 1
        self.n_jobs_run += len(jobs)
        for j, res in zip(jobs, results):
            j.response.status = "done"
            j.response.result = res
        return [j.response.job_id for j in jobs]

    def flush(self) -> int:
        """Run all pending buckets; returns the number of jobs executed."""
        n = 0
        for key in list(self._pending):
            n += len(self.flush_bucket(key))
        return n

    # -- retrieval ---------------------------------------------------------

    def poll(self, job_id: str) -> OptResponse:
        """Non-blocking status lookup; never triggers a bucket run."""
        return self._jobs[job_id].response

    def result(self, job_id: str, evict: bool = False) -> OptResponse:
        """Blocking fetch: flush the job's bucket if it has not run yet.

        ``evict=True`` drops the finished job's record (the Java server's
        fetch-once result semantics) — long-lived services use it so the job
        table does not grow without bound.
        """
        job = self._jobs[job_id]
        if job.response.status == "queued":
            self.flush_bucket(job.request.shape_class())
        if evict and job.response.status in ("done", "error"):
            del self._jobs[job_id]
        return job.response

    def stats(self) -> dict[str, int]:
        """Queue/dispatch counters for the service's ``stats`` op."""
        return {
            "submitted": len(self._jobs),
            "pending": sum(len(v) for v in self._pending.values()),
            "buckets_pending": len(self._pending),
            "dispatches": self.n_dispatches,
            "jobs_run": self.n_jobs_run,
        }
