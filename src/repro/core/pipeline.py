"""Two-stage explore→polish pipeline — one dispatch per stage (DESIGN.md §7).

The in-scan hybrid (``IslandConfig.polish``) interleaves local descent with
the global search. This module is the *staged* alternative the paper's DGA+ASD
experiments actually report: run the meta-heuristic to completion first, then
polish the final incumbent(s) with a batched local descent. Each stage is one
compiled dispatch — stage 1 is the engine's device-resident run (or the
jobs-axis ``minimize_many``), stage 2 is a single jitted
``optim.descent.make_polish`` call over the stacked incumbents, reusing the
same cached xla/pallas evaluator as the engine.

Budget accounting matches the engine's rule: stage-2 evaluations
(``polish_evals_per_point`` per incumbent) are added to each job's reported
``n_evals``, so pipelined results stay comparable with plain and in-scan
hybrid runs at equal budgets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import OptimizeResult
from repro.core.executor import make_batch_evaluator
from repro.core.islands import IslandOptimizer
from repro.functions.benchmarks import Function
from repro.optim import descent

Array = jax.Array

# Jitted stage-2 polishers, memoized like the executor's evaluator cache so
# repeated pipelines over one objective reuse the compiled program. Values
# carry the live f.fn and mesh so a recycled id() can never alias a dead entry.
_POLISH_JIT_CACHE: dict[tuple, tuple] = {}
_POLISH_JIT_CACHE_MAX = 64


def _stage2_fn(opt: IslandOptimizer, f: Function, pcfg: descent.PolishConfig):
    """Compiled ``(xs (J, dim), fs (J,)) -> (xs', fs')`` incumbent polisher."""
    ck = (f.name, id(f.fn), id(f.shift), f.bias, opt.cfg.dim, pcfg,
          opt.exec_cfg, id(opt.mesh))
    hit = _POLISH_JIT_CACHE.get(ck)
    if hit is not None and hit[0] is f.fn and hit[1] is opt.mesh:
        return hit[2]
    evaluator = make_batch_evaluator(f, opt.exec_cfg, opt.mesh)
    polish = jax.jit(descent.make_polish(f, evaluator, opt.cfg.dim, pcfg))
    _POLISH_JIT_CACHE[ck] = (f.fn, opt.mesh, polish)
    while len(_POLISH_JIT_CACHE) > _POLISH_JIT_CACHE_MAX:
        _POLISH_JIT_CACHE.pop(next(iter(_POLISH_JIT_CACHE)))
    return polish


def _merge(res: OptimizeResult, arg: Array, val: float,
           extra_evals: int) -> OptimizeResult:
    """Stage-2 outcome folded into the stage-1 result envelope."""
    if val < res.value:
        return OptimizeResult(arg=arg, value=val,
                              n_evals=res.n_evals + extra_evals,
                              n_gens=res.n_gens, history=res.history)
    return OptimizeResult(arg=res.arg, value=res.value,
                          n_evals=res.n_evals + extra_evals,
                          n_gens=res.n_gens, history=res.history)


def explore_then_polish(
    opt: IslandOptimizer,
    f: Function,
    key: Array,
    pcfg: descent.PolishConfig = descent.PolishConfig(steps=12),
) -> OptimizeResult:
    """Global explore, then polish the final incumbent: two dispatches total.

    Stage 1 is ``opt.minimize`` (one jitted run); stage 2 is one jitted polish
    of the returned incumbent. The polish evals are charged to ``n_evals``.
    """
    res = opt.minimize(f, key)
    polish = _stage2_fn(opt, f, pcfg)
    xs, fs = polish(jnp.asarray(res.arg)[None],
                    jnp.asarray([res.value], jnp.float32))
    per_point = descent.polish_evals_per_point(opt.cfg.dim, pcfg)
    return _merge(res, jax.device_get(xs[0]), float(fs[0]), per_point)


def explore_then_polish_many(
    opt: IslandOptimizer,
    f: Function,
    keys: Array,
    pcfg: descent.PolishConfig = descent.PolishConfig(steps=12),
) -> list[OptimizeResult]:
    """Jobs-axis pipeline: ONE ``minimize_many`` dispatch for the global
    stage, then ONE batched polish dispatch over all J final incumbents —
    however many jobs, exactly two compiled dispatches."""
    results = opt.minimize_many(f, keys)
    polish = _stage2_fn(opt, f, pcfg)
    xs = jnp.stack([jnp.asarray(r.arg) for r in results])
    fs = jnp.asarray([r.value for r in results], jnp.float32)
    xs2, fs2 = jax.device_get(polish(xs, fs))
    per_point = descent.polish_evals_per_point(opt.cfg.dim, pcfg)
    return [_merge(r, xs2[j], float(fs2[j]), per_point)
            for j, r in enumerate(results)]
