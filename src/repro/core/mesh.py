"""Island-engine device mesh — the paper's distributed message-passing layer
as a 1-D JAX mesh (DESIGN.md §8).

popt4jlib scales past one machine by running island populations in separate
processes that exchange migrants over sockets. The reproduction's analogue is
a :class:`MeshConfig`: islands are laid out over a one-axis device mesh and
the whole round scan runs under ``shard_map``, so each device owns
``n_islands / devices`` islands and migration crosses shard boundaries as a
``lax.ppermute`` ring exchange (``core.migration``) — the socket hop, compiled
to a collective.

The config is deliberately tiny (device count + axis name): it reuses the
serving side's conventions (``launch/mesh.py`` builds meshes in functions so
importing never touches jax device state; ``parallel/sharding.py`` names axes
once and threads ``PartitionSpec``s everywhere) without depending on either.

Off-accelerator the same layout runs on host-platform devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

which is how CI exercises the 8-shard ring on CPU (``tests/test_distributed``,
``benchmarks/distributed.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

ISLAND_AXIS = "islands"


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Layout of the island axis over devices: how many devices the leading
    (island) axis of every engine-state leaf is sharded across, and the mesh
    axis name the engine's collectives (``ppermute`` ring, ``all_gather``
    starvation/incumbent paths) refer to. ``devices=1`` is a valid degenerate
    mesh — the determinism contract (DESIGN.md §8) requires its trajectories
    to be bit-identical to the unsharded engine."""

    devices: int = 1          # devices the island axis shards over
    axis: str = ISLAND_AXIS   # mesh axis name used by the engine collectives

    def build(self) -> Mesh:
        """Materialize the 1-D mesh over the first ``devices`` local devices.

        Raises ``ValueError`` when the host exposes fewer devices — on CPU,
        raise the count with ``--xla_force_host_platform_device_count``.
        """
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        avail = jax.devices()
        if self.devices > len(avail):
            raise ValueError(
                f"MeshConfig wants {self.devices} devices but only "
                f"{len(avail)} are visible; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={self.devices}")
        return Mesh(np.asarray(avail[: self.devices]), (self.axis,))

    def local_islands(self, n_islands: int) -> int:
        """Islands each shard owns; validates the axis divides evenly."""
        if n_islands < 1 or n_islands % self.devices:
            raise ValueError(
                f"n_islands={n_islands} must be a positive multiple of "
                f"devices={self.devices} (equal-size shards)")
        return n_islands // self.devices


def island_specs(axis: str, n_replicated: int = 1) -> tuple[tuple, tuple]:
    """``(in_specs, out_specs)`` for the engine's round scan under
    ``shard_map``: the island-stacked state pytree (first argument) shards
    its leading axis over ``axis``; the ``n_replicated`` trailing scan inputs
    are replicated to every shard. The barrier engine replicates one input
    (the round-key table); the async engine (``IslandConfig.sync_policy ==
    "async"``, DESIGN.md §13) replicates three — round keys plus the
    step/deliver schedule masks — and every shard slices its local island
    rows out of them itself, mirroring the key-table discipline."""
    specs = PartitionSpec(axis)
    return ((specs, *([PartitionSpec()] * n_replicated)),
            (specs, PartitionSpec()))


def ring_perm(n_shards: int) -> list[tuple[int, int]]:
    """``ppermute`` permutation for the migration ring: shard d sends to
    d+1 (mod n) — island ``i``'s migrants reach island ``i+1`` when the
    boundary island crosses shards."""
    return [(d, (d + 1) % n_shards) for d in range(n_shards)]


def shard_map(f: Callable, mesh: Mesh, in_specs, out_specs) -> Callable:
    """Version-portable ``shard_map`` (replication checking off): jax >= 0.5
    exposes ``jax.shard_map`` with ``check_vma``; the 0.4.x line the repo
    supports only has ``jax.experimental.shard_map.shard_map`` with
    ``check_rep``. Every engine/executor shard_map goes through here."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def host_device_count() -> int:
    """Visible device count — the ceiling for ``MeshConfig.devices``."""
    return len(jax.devices())
