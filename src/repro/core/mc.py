"""MCS — parallel pure Monte-Carlo random search (popt4jlib.MonteCarlo).

The paper's benchmark baseline: draw uniformly from the box, keep the best.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
) -> MetaHeuristic:
    """Pure Monte-Carlo sampling policy — the paper's MCS baseline."""
    lo, hi = f.lo, f.hi

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {"pop": x, "fit": fit, "best_arg": x[i], "best_val": fit[i]}

    def gen(state: State, key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        better = fit[i] < state["best_val"]
        return {
            "pop": x, "fit": fit,
            "best_val": jnp.where(better, fit[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    return MetaHeuristic("mc", init, gen, evals_per_gen=pop, init_evals=pop)
