"""Distributed batch function evaluation — popt4jlib ``parallel.distributed`` in JAX.

The Java library's ``PDBatchTaskExecutorSrv/Clt/Wrk`` network distributes an array of
``TaskObject``s by splitting it into equal-size chunks, one per available worker, and
re-submitting failed batches once. On a TPU mesh the worker pool is the mesh itself:

  * equal-size chunking  -> sharding the population axis over a mesh axis
                            (``shard_map`` with a padded, evenly divisible axis)
  * init-cmd broadcast   -> replicated closure state (captured constants are
                            broadcast to every device by XLA)
  * retry-once-then-evict -> non-finite results are re-evaluated once on a slightly
                            perturbed argument; still-bad results are marked +inf
                            (the candidate is "evicted" from selection)
  * accumulator/reducer  -> the caller reduces with jnp/min-collectives

The *evaluation backend* — how one chunk of candidates becomes fitness values —
is pluggable (POLO-style policy/execution separation, DESIGN.md §3):

  * ``xla``     vmap of the pure-jnp definition; works for every function.
  * ``pallas``  dispatch to the fused ``bench_eval`` VMEM kernel for functions
                with an entry in ``kernels.registry`` (interpret mode off-TPU,
                so CPU tests exercise the same code path).

Both compose with the shard_map wrapper: the mesh distributes chunks, the
backend evaluates each chunk. The executor is a *pure function* of its inputs,
so XLA can fuse it into the surrounding generation step — the distributed
map/reduce costs nothing extra when the mesh is trivial (CPU tests) and lowers
to balanced SPMD on the pod.

Under the island-sharded engine (``core.mesh.MeshConfig``, DESIGN.md §8) the
executor is *per-shard*: the engine traces the plain (``mesh_axis=None``)
evaluator inside its own ``shard_map``, so each device's island block carries
its own EvalBackend instance and no nested shard_map is ever built — the
population-sharding path below is for the single-island Table-I layout only.

The evaluator cache below also serves the hybrid memetic layer (DESIGN.md §6):
``IslandOptimizer._polish`` rebuilds the evaluator for its gradient probes and
line-search ladders and — because ``make_batch_evaluator`` memoizes on
(objective, config, mesh) — receives the SAME callable the generation steps
use, keeping polish on the identical xla/pallas path with zero extra compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.mesh import shard_map as _shard_map
from repro.functions.benchmarks import Function
from repro.kernels import registry as kreg
from repro.kernels.autotune import KernelConfig
from repro.kernels.bench_eval import bench_eval as _bench_eval

Array = jax.Array

BACKENDS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class ExecutorConfig:
    """How candidate batches are evaluated: backend choice, retry policy and
    the mesh axis the population is sharded over (DESIGN.md §3)."""

    backend: str = "xla"          # evaluation backend: "xla" | "pallas"
    retry_bad: bool = True        # paper: resubmit a failed batch once
    retry_eps: float = 1e-6       # perturbation used for the retry evaluation
    mesh_axis: str | tuple[str, ...] | None = None  # population-sharding axis(es)
    interpret: bool | None = None # pallas interpret mode; None = auto (off-TPU)
    # One KernelConfig threaded to EVERY Pallas kernel entry this config
    # touches — the pallas eval backend here and the fused generation kernels
    # the engine builds (islands/portfolio inject it into policy makers).
    # Unset fields are autotuned per shape-class by kernels.autotune.
    kernel: KernelConfig = KernelConfig()


def _pallas_interpret(cfg: ExecutorConfig) -> bool:
    if cfg.interpret is not None:
        return cfg.interpret
    if cfg.kernel.interpret is not None:
        return cfg.kernel.interpret
    return jax.default_backend() != "tpu"


def _make_eval_once(f: Function, cfg: ExecutorConfig) -> Callable[[Array], Array]:
    """Resolve the per-chunk evaluation backend for ``f``."""
    if cfg.backend == "xla":
        return lambda pop: jax.vmap(f.fn)(pop)
    if cfg.backend == "pallas":
        spec = kreg.get_spec(f.name)   # KeyError for unregistered functions
        kc = dataclasses.replace(cfg.kernel, interpret=_pallas_interpret(cfg))

        def eval_pallas(pop: Array) -> Array:
            return _bench_eval(pop, spec.eval_tag, shift=f.shift,
                               bias=f.bias, kernel_cfg=kc)

        return eval_pallas
    raise ValueError(f"unknown backend {cfg.backend!r}; expected one of {BACKENDS}")


# Per-bucket evaluator cache: the scheduler rebuilds an optimizer per bucket
# flush, and a stable evaluator identity keeps the downstream jit caches warm
# (a fresh closure would recompile every generation step). Keyed by
# Function.cache_token() — a GC-stable identity token plus the shift content,
# so a recycled id() can never silently alias a dead objective or a dead
# shift array — plus config and mesh; values still carry the live objects as
# a belt-and-braces identity guard. FIFO-capped: keys are request-controlled,
# so an adversarial traffic mix must recompile rather than grow memory
# unboundedly.
_EVALUATOR_CACHE: dict[tuple, tuple] = {}
_EVALUATOR_CACHE_MAX = 256


def make_batch_evaluator(
    f: Function,
    cfg: ExecutorConfig = ExecutorConfig(),
    mesh: Mesh | None = None,
) -> Callable[[Array], Array]:
    """Return ``evaluate(pop: (P, D)) -> (P,)`` with the executor semantics above.

    Evaluators are memoized on ``(objective identity, cfg, mesh identity)`` —
    repeated builds for the same shape-class (scheduler buckets, benchmark
    loops) return the same callable.
    """
    # id(mesh) is safe here because live cache entries hold the mesh strongly
    # (hit[1]), so a colliding recycled address always fails the identity
    # guard below and rebuilds instead of serving a stale program.
    ck = (*f.cache_token(), cfg, id(mesh))
    hit = _EVALUATOR_CACHE.get(ck)
    if hit is not None and hit[0] is f.fn and hit[1] is mesh:
        return hit[2]

    _eval_once = _make_eval_once(f, cfg)

    def evaluate(pop: Array) -> Array:
        fit = _eval_once(pop)
        if cfg.retry_bad:
            bad = ~jnp.isfinite(fit)
            # Retry the failed "batch" once on a perturbed argument (the SPMD
            # analogue of handing the task to another worker).
            retried = _eval_once(pop + cfg.retry_eps)
            fit = jnp.where(bad, retried, fit)
            # Second failure -> evict from the candidate pool.
            fit = jnp.where(jnp.isfinite(fit), fit, jnp.inf)
        return fit

    if mesh is None or cfg.mesh_axis is None:
        _cache_put(ck, (f.fn, mesh, evaluate))
        return evaluate

    axis = cfg.mesh_axis
    spec_in = P(axis, None)
    spec_out = P(axis)

    def sharded_evaluate(pop: Array) -> Array:
        # Equal-size chunks per worker: pad P to a multiple of the axis size.
        n = 1
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            n *= mesh.shape[a]
        pcount = pop.shape[0]
        pad = (-pcount) % n
        padded = jnp.pad(pop, ((0, pad), (0, 0)))
        out = _shard_map(
            evaluate, mesh, in_specs=(spec_in,), out_specs=spec_out,
        )(padded)
        return out[:pcount]

    _cache_put(ck, (f.fn, mesh, sharded_evaluate))
    return sharded_evaluate


def _cache_put(key: tuple, val: tuple) -> None:
    _EVALUATOR_CACHE[key] = val
    while len(_EVALUATOR_CACHE) > _EVALUATOR_CACHE_MAX:
        _EVALUATOR_CACHE.pop(next(iter(_EVALUATOR_CACHE)))


def distributed_map_reduce(
    mesh: Mesh,
    axis: str,
    map_fn: Callable[[Array], Array],
    reduce_op: str,
    xs: Array,
) -> Array:
    """popt4jlib distributed map/reduce operator: map over the sharded leading axis,
    reduce with a collective (the "accumulator server")."""

    def body(chunk: Array) -> Array:
        mapped = jax.vmap(map_fn)(chunk)
        local = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[reduce_op](mapped, axis=0)
        return jax.lax.psum(local, axis) if reduce_op == "sum" else (
            jax.lax.pmin(local, axis) if reduce_op == "min" else jax.lax.pmax(local, axis)
        )

    return _shard_map(
        body, mesh, in_specs=(P(axis),), out_specs=P(),
    )(xs)
