"""DDE — island-model Differential Evolution (popt4jlib.DE).

Implements DE/rand/1/bin and DE/best/1/bin (the paper's two variants) and the
paper's "non-determinism-ok" flag:

  barrier_mode="sync"     the barrier-corrected semantics: every trial vector of a
                          generation reads the *same* snapshot of the population
                          (deterministic in Java only with the barrier; always
                          deterministic here).
  barrier_mode="chunked"  the barrier-free semantics: the population is updated in
                          ``n_chunks`` blocks and later blocks read earlier blocks'
                          fresh writes — the reproducible SPMD analogue of the Java
                          threads racing on the shared solution array. One fewer
                          population snapshot per generation (cheaper on TPU: no
                          second all-gather when the population axis is sharded).

``fused=True`` routes the whole generation — mutation, crossover, evaluation,
selection — through the fused ``kernels.de_step`` Pallas kernel (one HBM read /
write of the population instead of five round-trips) via the engine's
``step_override`` hook. Requires DE/rand/1/bin and an objective registered in
``kernels.registry``; runs in interpret mode off-TPU so the same path is
exercised on CPU.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, track_best, uniform_init
from repro.functions.benchmarks import Function
from repro.kernels import registry as kreg
from repro.kernels.autotune import KernelConfig
from repro.kernels.de_step import de_step as _de_step_kernel

Array = jax.Array


def _distinct3(key: Array, P: int) -> tuple[Array, Array, Array]:
    """Three random indices per row, each != the row index (mod-shift trick)."""
    i = jnp.arange(P)
    k1, k2, k3 = jax.random.split(key, 3)
    ra = (i + 1 + jax.random.randint(k1, (P,), 0, P - 1)) % P
    rb = (i + 1 + jax.random.randint(k2, (P,), 0, P - 1)) % P
    rc = (i + 1 + jax.random.randint(k3, (P,), 0, P - 1)) % P
    return ra, rb, rc


def _trials(pop: Array, best: Array, key: Array, w: float, px: float,
            strategy: str) -> Array:
    P, D = pop.shape
    ksel, kcr, kj = jax.random.split(key, 3)
    ra, rb, rc = _distinct3(ksel, P)
    base = pop[ra] if strategy == "rand1bin" else jnp.broadcast_to(best, pop.shape)
    mutant = base + w * (pop[rb] - pop[rc])
    # binomial crossover with a guaranteed dimension
    cross = jax.random.uniform(kcr, (P, D)) < px
    jrand = jax.random.randint(kj, (P,), 0, D)
    cross = cross | (jnp.arange(D)[None, :] == jrand[:, None])
    return jnp.where(cross, mutant, pop)


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    w: float = 0.5,
    px: float = 0.2,
    strategy: str = "rand1bin",        # rand1bin | best1bin
    barrier_mode: str = "sync",        # sync | chunked ("non-determinism-ok")
    n_chunks: int = 8,
    fused: bool = False,               # whole generation in one Pallas kernel
    interpret: bool | None = None,     # fused-kernel interpret mode; None = auto
    kernel_cfg: KernelConfig | None = None,
) -> MetaHeuristic:
    """Differential Evolution per-island policy (DE/rand/1/bin, DE/best/1/bin)."""
    assert strategy in ("rand1bin", "best1bin")
    assert barrier_mode in ("sync", "chunked")
    lo, hi = f.lo, f.hi

    def init(key: Array) -> State:
        p = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(p)
        i = jnp.argmin(fit)
        return {"pop": p, "fit": fit, "best_arg": p[i], "best_val": fit[i]}

    def gen_sync(state: State, key: Array) -> State:
        p, fit = state["pop"], state["fit"]
        trial = clip_box(_trials(p, state["best_arg"], key, w, px, strategy), lo, hi)
        tfit = evaluator(trial)
        better = tfit <= fit
        p = jnp.where(better[:, None], trial, p)
        fit = jnp.where(better, tfit, fit)
        return track_best(state, p, fit)

    csz = max(1, pop // n_chunks) if barrier_mode == "chunked" else pop
    n_eff_chunks = (pop + csz - 1) // csz

    def gen_chunked(state: State, key: Array) -> State:
        # Later chunks read earlier chunks' already-updated vectors ("stale-ok").
        def body(c: int, carry: tuple[Array, Array]) -> tuple[Array, Array]:
            p, fit = carry
            ck = jax.random.fold_in(key, c)
            start = c * csz
            trial_all = clip_box(
                _trials(p, p[jnp.argmin(fit)], ck, w, px, strategy), lo, hi)
            trial = jax.lax.dynamic_slice_in_dim(trial_all, start, csz, 0)
            cur_f = jax.lax.dynamic_slice_in_dim(fit, start, csz, 0)
            cur_p = jax.lax.dynamic_slice_in_dim(p, start, csz, 0)
            tfit = evaluator(trial)
            better = tfit <= cur_f
            newp = jnp.where(better[:, None], trial, cur_p)
            newf = jnp.where(better, tfit, cur_f)
            p = jax.lax.dynamic_update_slice_in_dim(p, newp, start, 0)
            fit = jax.lax.dynamic_update_slice_in_dim(fit, newf, start, 0)
            return p, fit

        p, fit = jax.lax.fori_loop(0, n_eff_chunks, body, (state["pop"], state["fit"]))
        return track_best(state, p, fit)

    step_override = None
    if fused:
        assert strategy == "rand1bin", "fused DE implements DE/rand/1/bin only"
        spec = kreg.get_spec(f.name)   # KeyError if no kernel for this objective
        assert spec.fused_de, f.name

        def gen_fused(state: State, key: Array) -> State:
            # Same key discipline as gen_sync/_trials, so the fused and XLA
            # paths draw identical donors/crossover masks on a fixed seed.
            ksel, kcr, kj = jax.random.split(key, 3)
            ra, rb, rc = _distinct3(ksel, pop)
            u = jax.random.uniform(kcr, (pop, dim))
            jrand = jax.random.randint(kj, (pop,), 0, dim)
            new_pop, new_fit = _de_step_kernel(
                state["pop"], state["fit"], jnp.stack([ra, rb, rc]), u, jrand,
                fn=spec.eval_tag, shift=f.shift, bias=f.bias,
                w=w, px=px, lo=lo, hi=hi, interpret=interpret,
                kernel_cfg=kernel_cfg,
            )
            return track_best(state, new_pop, new_fit)

        step_override = gen_fused

    gen = gen_sync if barrier_mode == "sync" else gen_chunked
    # Chunked mode evaluates n_eff_chunks fixed-size blocks of csz rows; when
    # csz does not divide pop the clamped slices overlap and the generation
    # really consumes csz * n_eff_chunks evaluations, not pop — charge what
    # the evaluator actually runs (parity enforced for every registered
    # policy by tests/test_metaheuristics.py::test_evals_per_gen_parity).
    evals = csz * n_eff_chunks if barrier_mode == "chunked" else pop
    return MetaHeuristic("de", init, gen, evals_per_gen=evals, init_evals=pop,
                         step_override=step_override)
