"""Island migration policies — popt4jlib's DGA/DPSO/DDE migration models.

Operates on island-stacked arrays ``pop: (I, P, D)``, ``fit: (I, P)``. Every
policy has two forms selected by the ``axis`` argument (DESIGN.md §8):

* ``axis=None`` — the island axis is resident on one device; migration is a
  plain roll/gather over it.
* ``axis=<mesh axis>`` (inside ``shard_map``, ``n_shards`` devices) — each
  shard holds ``I_local = I / n_shards`` islands. The ring becomes a local
  roll plus ONE ``lax.ppermute`` exchange of the boundary island's migrants —
  the Java library's socket hop, compiled to a collective-permute — and the
  starvation policy degrades to an all-gather-on-cadence path: gather the
  stacked populations, apply the host-side policy verbatim, slice the local
  block back. Both forms compute identical values (the sharded ring
  reassembles exactly the rolled migrant tensor), which is what the engine's
  determinism contract rests on.

Policies:
  ring        counter-clock-wise unidirectional ring (the DPSO/DDE default):
              island i sends its best ``k`` individuals to island i+1 (mod I),
              which adopts any migrant better than its current worst.
  starvation  the DGA/DGABH model: an island whose live population is 0, or less
              than (max island population / 2.5), becomes the immigration host;
              every other island sends its best individual there. At most
              ``k``<=2 migrants leave an island per sync round (paper limit).
  none        isolated islands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mesh import ring_perm

Array = jax.Array

STARVATION_RATIO = 2.5  # the paper's "population of another island divided by 2.5"


def _replace_worst(pop: Array, fit: Array, mig: Array, migf: Array):
    """Per-island: replace the worst-k individuals with migrants when the migrant
    is better. pop (P,D), fit (P,), mig (k,D), migf (k,)."""
    k = mig.shape[0]
    worst = jnp.argsort(fit)[-k:]                      # indices of worst-k
    cur = fit[worst]
    take = migf < cur
    newf = jnp.where(take, migf, cur)
    newp = jnp.where(take[:, None], mig, pop[worst])
    return pop.at[worst].set(newp), fit.at[worst].set(newf)


def ring(pop: Array, fit: Array, k: int = 2,
         axis: str | None = None, n_shards: int = 1):
    """Counter-clock-wise ring migration of the best-k per island.

    With ``axis`` set (inside ``shard_map``), the global roll-by-one becomes a
    local roll plus a ``ppermute`` handoff of the last local island's migrants
    to the next shard's first island — one boundary exchange per sync round,
    regardless of how many islands a shard holds.
    """
    if axis is not None and n_shards > 1:
        best = jnp.argsort(fit, axis=1)[:, :k]                     # (I_l,k)
        mig = jnp.take_along_axis(pop, best[..., None], axis=1)    # (I_l,k,D)
        migf = jnp.take_along_axis(fit, best, axis=1)              # (I_l,k)
        perm = ring_perm(n_shards)
        prev_m = jax.lax.ppermute(mig[-1], axis, perm)             # (k,D)
        prev_f = jax.lax.ppermute(migf[-1], axis, perm)            # (k,)
        mig = jnp.concatenate([prev_m[None], mig[:-1]], axis=0)
        migf = jnp.concatenate([prev_f[None], migf[:-1]], axis=0)
        return jax.vmap(_replace_worst)(pop, fit, mig, migf)
    if pop.shape[0] <= 1:
        return pop, fit
    best = jnp.argsort(fit, axis=1)[:, :k]                         # (I,k)
    mig = jnp.take_along_axis(pop, best[..., None], axis=1)        # (I,k,D)
    migf = jnp.take_along_axis(fit, best, axis=1)                  # (I,k)
    # i -> i+1: destination i receives from i-1  (ppermute on a sharded axis)
    mig = jnp.roll(mig, 1, axis=0)
    migf = jnp.roll(migf, 1, axis=0)
    return jax.vmap(_replace_worst)(pop, fit, mig, migf)


def starvation(pop: Array, fit: Array, k: int = 2, alive: Array | None = None,
               axis: str | None = None, n_shards: int = 1):
    """DGA starvation-based immigration: weakest island hosts everyone's best.

    ``alive`` (I, P) marks live individuals (aging model); dead slots carry +inf
    fitness. Migrants land in the host island's worst/dead slots.

    The policy is inherently global (the host is the argmin over every
    island's live count), so its sharded form is the documented all-gather
    degradation (DESIGN.md §8): gather the island-stacked arrays once per sync
    round, run the host-side policy unchanged on the gathered copy, and slice
    this shard's island block back out — bit-identical to the unsharded policy
    by construction, at the cost of one all-gather on the migration cadence.
    """
    if axis is not None and n_shards > 1:
        gpop = jax.lax.all_gather(pop, axis, tiled=True)           # (I,P,D)
        gfit = jax.lax.all_gather(fit, axis, tiled=True)           # (I,P)
        galive = (None if alive is None
                  else jax.lax.all_gather(alive, axis, tiled=True))
        npop, nfit = starvation(gpop, gfit, k, galive)
        i_local = pop.shape[0]
        start = jax.lax.axis_index(axis) * i_local
        return (jax.lax.dynamic_slice_in_dim(npop, start, i_local, 0),
                jax.lax.dynamic_slice_in_dim(nfit, start, i_local, 0))
    if pop.shape[0] <= 1:
        return pop, fit
    if alive is None:
        alive = jnp.isfinite(fit)
    counts = alive.sum(axis=1)                                     # (I,)
    host = jnp.argmin(counts)
    starving = (counts[host] == 0) | (counts[host].astype(jnp.float32)
                                      < counts.max().astype(jnp.float32) / STARVATION_RATIO)

    k = min(k, 2)  # paper: at most 2 migrants leave an island per generation
    best = jnp.argsort(fit, axis=1)[:, :k]                         # (I,k)
    mig = jnp.take_along_axis(pop, best[..., None], axis=1)        # (I,k,D)
    migf = jnp.take_along_axis(fit, best, axis=1)                  # (I,k)
    # Donors: every island except the host. Mask the host's own contribution.
    donor_mask = (jnp.arange(pop.shape[0]) != host)[:, None]       # (I,1)
    migf = jnp.where(donor_mask, migf, jnp.inf)
    flat_m = mig.reshape(-1, pop.shape[-1])                        # (I*k, D)
    flat_f = migf.reshape(-1)                                      # (I*k,)

    # Host adopts the best arrivals into its worst slots.
    hpop, hfit = pop[host], fit[host]
    order = jnp.argsort(flat_f)
    nslots = min(flat_f.shape[0], hfit.shape[0])
    arrivals = flat_m[order][:nslots]
    arrivalf = flat_f[order][:nslots]
    hpop2, hfit2 = _replace_worst(hpop, hfit, arrivals, arrivalf)
    hpop2 = jnp.where(starving, hpop2, hpop)
    hfit2 = jnp.where(starving, hfit2, hfit)
    return pop.at[host].set(hpop2), fit.at[host].set(hfit2)


def migrate(policy: str, pop: Array, fit: Array, k: int = 2,
            alive: Array | None = None,
            axis: str | None = None, n_shards: int = 1):
    """Dispatch to a migration policy by name: ring | starvation | none.
    ``axis``/``n_shards`` select the sharded (inside-``shard_map``) form."""
    if policy == "ring":
        return ring(pop, fit, k, axis=axis, n_shards=n_shards)
    if policy == "starvation":
        return starvation(pop, fit, k, alive, axis=axis, n_shards=n_shards)
    if policy == "none":
        return pop, fit
    raise ValueError(f"unknown migration policy {policy!r}")
