"""Island migration policies — popt4jlib's DGA/DPSO/DDE migration models.

Operates on island-stacked arrays ``pop: (I, P, D)``, ``fit: (I, P)``. Every
policy has two forms selected by the ``axis`` argument (DESIGN.md §8):

* ``axis=None`` — the island axis is resident on one device; migration is a
  plain roll/gather over it.
* ``axis=<mesh axis>`` (inside ``shard_map``, ``n_shards`` devices) — each
  shard holds ``I_local = I / n_shards`` islands. The ring becomes a local
  roll plus ONE ``lax.ppermute`` exchange of the boundary island's migrants —
  the Java library's socket hop, compiled to a collective-permute — and the
  starvation policy degrades to an all-gather-on-cadence path: gather the
  stacked populations, apply the host-side policy verbatim, slice the local
  block back. Both forms compute identical values (the sharded ring
  reassembles exactly the rolled migrant tensor), which is what the engine's
  determinism contract rests on.

Policies:
  ring        counter-clock-wise unidirectional ring (the DPSO/DDE default):
              island i sends its best ``k`` individuals to island i+1 (mod I),
              which adopts any migrant better than its current worst.
  starvation  the DGA/DGABH model: an island whose live population is 0, or less
              than (max island population / 2.5), becomes the immigration host;
              every other island sends its best individual there. At most
              ``k``<=2 migrants leave an island per sync round (paper limit).
  none        isolated islands.

Async mailbox (DESIGN.md §13): the staleness-bounded alternative to the
lockstep exchange. Each island owns a fixed-shape ring buffer of migrant
batches (``mailbox_init``); on the ticks it completes a round it posts its
best-k to its ring successor's buffer tagged with its local round counter
(``mailbox_post`` — a full ring overwrites the oldest entry), and adopts the
newest entry whose staleness (receiver round minus sender tag) is at most
``max_staleness`` through the SAME ``_replace_worst`` rule the barrier ring
uses (``mailbox_adopt`` — staler entries are never adopted). With every
island on the barrier cadence and ``max_staleness=0`` the adopted batch each
tick is exactly the rolled migrant tensor ``ring`` computes, which is what
the async engine's degradation contract rests on
(``tests/test_async_islands.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mesh import ring_perm

Array = jax.Array

STARVATION_RATIO = 2.5  # the paper's "population of another island divided by 2.5"


def _replace_worst(pop: Array, fit: Array, mig: Array, migf: Array):
    """Per-island: replace the worst-k individuals with migrants when the migrant
    is better. pop (P,D), fit (P,), mig (k,D), migf (k,)."""
    k = mig.shape[0]
    worst = jnp.argsort(fit)[-k:]                      # indices of worst-k
    cur = fit[worst]
    take = migf < cur
    newf = jnp.where(take, migf, cur)
    newp = jnp.where(take[:, None], mig, pop[worst])
    return pop.at[worst].set(newp), fit.at[worst].set(newf)


def ring(pop: Array, fit: Array, k: int = 2,
         axis: str | None = None, n_shards: int = 1):
    """Counter-clock-wise ring migration of the best-k per island.

    With ``axis`` set (inside ``shard_map``), the global roll-by-one becomes a
    local roll plus a ``ppermute`` handoff of the last local island's migrants
    to the next shard's first island — one boundary exchange per sync round,
    regardless of how many islands a shard holds.
    """
    if axis is not None and n_shards > 1:
        best = jnp.argsort(fit, axis=1)[:, :k]                     # (I_l,k)
        mig = jnp.take_along_axis(pop, best[..., None], axis=1)    # (I_l,k,D)
        migf = jnp.take_along_axis(fit, best, axis=1)              # (I_l,k)
        perm = ring_perm(n_shards)
        prev_m = jax.lax.ppermute(mig[-1], axis, perm)             # (k,D)
        prev_f = jax.lax.ppermute(migf[-1], axis, perm)            # (k,)
        mig = jnp.concatenate([prev_m[None], mig[:-1]], axis=0)
        migf = jnp.concatenate([prev_f[None], migf[:-1]], axis=0)
        return jax.vmap(_replace_worst)(pop, fit, mig, migf)
    if pop.shape[0] <= 1:
        return pop, fit
    best = jnp.argsort(fit, axis=1)[:, :k]                         # (I,k)
    mig = jnp.take_along_axis(pop, best[..., None], axis=1)        # (I,k,D)
    migf = jnp.take_along_axis(fit, best, axis=1)                  # (I,k)
    # i -> i+1: destination i receives from i-1  (ppermute on a sharded axis)
    mig = jnp.roll(mig, 1, axis=0)
    migf = jnp.roll(migf, 1, axis=0)
    return jax.vmap(_replace_worst)(pop, fit, mig, migf)


def starvation(pop: Array, fit: Array, k: int = 2, alive: Array | None = None,
               axis: str | None = None, n_shards: int = 1):
    """DGA starvation-based immigration: weakest island hosts everyone's best.

    ``alive`` (I, P) marks live individuals (aging model); dead slots carry +inf
    fitness. Migrants land in the host island's worst/dead slots.

    The policy is inherently global (the host is the argmin over every
    island's live count), so its sharded form is the documented all-gather
    degradation (DESIGN.md §8): gather the island-stacked arrays once per sync
    round, run the host-side policy unchanged on the gathered copy, and slice
    this shard's island block back out — bit-identical to the unsharded policy
    by construction, at the cost of one all-gather on the migration cadence.
    """
    if axis is not None and n_shards > 1:
        gpop = jax.lax.all_gather(pop, axis, tiled=True)           # (I,P,D)
        gfit = jax.lax.all_gather(fit, axis, tiled=True)           # (I,P)
        galive = (None if alive is None
                  else jax.lax.all_gather(alive, axis, tiled=True))
        npop, nfit = starvation(gpop, gfit, k, galive)
        i_local = pop.shape[0]
        start = jax.lax.axis_index(axis) * i_local
        return (jax.lax.dynamic_slice_in_dim(npop, start, i_local, 0),
                jax.lax.dynamic_slice_in_dim(nfit, start, i_local, 0))
    if pop.shape[0] <= 1:
        return pop, fit
    if alive is None:
        alive = jnp.isfinite(fit)
    counts = alive.sum(axis=1)                                     # (I,)
    host = jnp.argmin(counts)
    starving = (counts[host] == 0) | (counts[host].astype(jnp.float32)
                                      < counts.max().astype(jnp.float32) / STARVATION_RATIO)

    k = min(k, 2)  # paper: at most 2 migrants leave an island per generation
    best = jnp.argsort(fit, axis=1)[:, :k]                         # (I,k)
    mig = jnp.take_along_axis(pop, best[..., None], axis=1)        # (I,k,D)
    migf = jnp.take_along_axis(fit, best, axis=1)                  # (I,k)
    # Donors: every island except the host. Mask the host's own contribution.
    donor_mask = (jnp.arange(pop.shape[0]) != host)[:, None]       # (I,1)
    migf = jnp.where(donor_mask, migf, jnp.inf)
    flat_m = mig.reshape(-1, pop.shape[-1])                        # (I*k, D)
    flat_f = migf.reshape(-1)                                      # (I*k,)

    # Host adopts the best arrivals into its worst slots.
    hpop, hfit = pop[host], fit[host]
    order = jnp.argsort(flat_f)
    nslots = min(flat_f.shape[0], hfit.shape[0])
    arrivals = flat_m[order][:nslots]
    arrivalf = flat_f[order][:nslots]
    hpop2, hfit2 = _replace_worst(hpop, hfit, arrivals, arrivalf)
    hpop2 = jnp.where(starving, hpop2, hpop)
    hfit2 = jnp.where(starving, hfit2, hfit)
    return pop.at[host].set(hpop2), fit.at[host].set(hfit2)


# -- async staleness-bounded mailbox (DESIGN.md §13) ------------------------

MAILBOX_KEYS = ("mbox_pop", "mbox_fit", "mbox_tag", "mbox_head",
                "round_ctr", "stale_seen")


def mailbox_init(n_islands: int, slots: int, k: int, dim: int) -> dict[str, Array]:
    """Fresh per-island mailbox state, carried alongside the policy state in
    the async engine's scan (keys in :data:`MAILBOX_KEYS`):

    * ``mbox_pop (I, S, k, D)`` / ``mbox_fit (I, S, k)`` — ``S`` ring slots of
      k-migrant batches per island (empty slots carry +inf fitness);
    * ``mbox_tag (I, S)`` — the sender's round counter per slot, -1 = empty;
    * ``mbox_head (I,)`` — each ring's write cursor (wraps = overwrite oldest);
    * ``round_ctr (I,)`` — per-island completed-round counters, the clocks
      staleness is measured against;
    * ``stale_seen (I,)`` — high-water mark of adopted-migrant staleness
      (-1 until an adoption happens), the observability hook the staleness
      bound is asserted through.
    """
    i, s = n_islands, slots
    return {
        "mbox_pop": jnp.zeros((i, s, k, dim), jnp.float32),
        "mbox_fit": jnp.full((i, s, k), jnp.inf, jnp.float32),
        "mbox_tag": jnp.full((i, s), -1, jnp.int32),
        "mbox_head": jnp.zeros((i,), jnp.int32),
        "round_ctr": jnp.zeros((i,), jnp.int32),
        "stale_seen": jnp.full((i,), -1, jnp.int32),
    }


def mailbox_post(mbox: dict[str, Array], pop: Array, fit: Array, k: int,
                 post: Array, axis: str | None = None, n_shards: int = 1
                 ) -> dict[str, Array]:
    """Each island posts its best-k batch to its ring successor's mailbox.

    ``post (I,)`` gates per *sender* — an island posts only on ticks it
    completed a round AND the delivery schedule fired (a False models a
    dropped message; the batch is simply lost, like a dropped datagram).
    The batch lands at the receiver's write head tagged with the sender's
    ``round_ctr``; a full ring overwrites the oldest entry. Inside
    ``shard_map`` the boundary island's batch crosses shards as one
    ``ppermute`` — the same single hop the barrier ring pays.
    """
    best = jnp.argsort(fit, axis=1)[:, :k]                         # (I,k)
    mig = jnp.take_along_axis(pop, best[..., None], axis=1)        # (I,k,D)
    migf = jnp.take_along_axis(fit, best, axis=1)                  # (I,k)
    tag = mbox["round_ctr"]
    post = post.astype(jnp.int32)
    if axis is not None and n_shards > 1:
        perm = ring_perm(n_shards)
        pm = jax.lax.ppermute(mig[-1], axis, perm)
        pf_ = jax.lax.ppermute(migf[-1], axis, perm)
        pt = jax.lax.ppermute(tag[-1], axis, perm)
        pg = jax.lax.ppermute(post[-1], axis, perm)
        in_m = jnp.concatenate([pm[None], mig[:-1]], axis=0)
        in_f = jnp.concatenate([pf_[None], migf[:-1]], axis=0)
        in_t = jnp.concatenate([pt[None], tag[:-1]], axis=0)
        in_g = jnp.concatenate([pg[None], post[:-1]], axis=0)
    else:
        in_m, in_f = jnp.roll(mig, 1, axis=0), jnp.roll(migf, 1, axis=0)
        in_t, in_g = jnp.roll(tag, 1, axis=0), jnp.roll(post, 1, axis=0)
    slots = mbox["mbox_tag"].shape[1]

    def write(bp, bf, bt, h, m, f, t, g):
        keep = g > 0
        sel = lambda a, b: jnp.where(keep, a, b)  # noqa: E731
        return (sel(bp.at[h].set(m), bp), sel(bf.at[h].set(f), bf),
                sel(bt.at[h].set(t), bt), jnp.where(keep, (h + 1) % slots, h))

    bp, bf, bt, head = jax.vmap(write)(
        mbox["mbox_pop"], mbox["mbox_fit"], mbox["mbox_tag"],
        mbox["mbox_head"], in_m, in_f, in_t, in_g)
    return {**mbox, "mbox_pop": bp, "mbox_fit": bf, "mbox_tag": bt,
            "mbox_head": head}


def mailbox_adopt(mbox: dict[str, Array], pop: Array, fit: Array,
                  max_staleness: int, gate: Array
                  ) -> tuple[Array, Array, dict[str, Array]]:
    """Each island adopts the newest mailbox batch whose staleness — its own
    ``round_ctr`` minus the sender's tag — is at most ``max_staleness``,
    through the same worst-k replacement rule the barrier ring uses.

    Entries staler than the bound are never adopted (they age in the ring
    until overwritten); an adopted slot is consumed (tag reset to -1) so a
    batch is adopted at most once. ``gate (I,)`` restricts adoption to
    islands that completed a round this tick. ``stale_seen`` records the
    high-water mark of adopted staleness. Returns ``(pop, fit, mbox)``.
    """
    tags = mbox["mbox_tag"]                                        # (I,S)
    stale = mbox["round_ctr"][:, None] - tags
    valid = (tags >= 0) & (stale <= max_staleness)
    keyed = jnp.where(valid, tags, -1)
    slot = jnp.argmax(keyed, axis=1)                  # newest valid per island
    has = jnp.take_along_axis(keyed, slot[:, None], axis=1)[:, 0] >= 0
    take = has & gate
    m = jnp.take_along_axis(
        mbox["mbox_pop"], slot[:, None, None, None], axis=1)[:, 0]  # (I,k,D)
    f = jnp.take_along_axis(mbox["mbox_fit"], slot[:, None, None], axis=1)[:, 0]
    npop, nfit = jax.vmap(_replace_worst)(pop, fit, m, f)
    pop = jnp.where(take[:, None, None], npop, pop)
    fit = jnp.where(take[:, None], nfit, fit)
    consumed = tags.at[jnp.arange(tags.shape[0]), slot].set(-1)
    new_tags = jnp.where(take[:, None], consumed, tags)
    st = jnp.take_along_axis(stale, slot[:, None], axis=1)[:, 0]
    seen = jnp.where(take, jnp.maximum(mbox["stale_seen"], st),
                     mbox["stale_seen"])
    return pop, fit, {**mbox, "mbox_tag": new_tags, "stale_seen": seen}


def migrate(policy: str, pop: Array, fit: Array, k: int = 2,
            alive: Array | None = None,
            axis: str | None = None, n_shards: int = 1):
    """Dispatch to a migration policy by name: ring | starvation | none.
    ``axis``/``n_shards`` select the sharded (inside-``shard_map``) form."""
    if policy == "ring":
        return ring(pop, fit, k, axis=axis, n_shards=n_shards)
    if policy == "starvation":
        return starvation(pop, fit, k, alive, axis=axis, n_shards=n_shards)
    if policy == "none":
        return pop, fit
    raise ValueError(f"unknown migration policy {policy!r}")
