"""DSA — multi-threaded Simulated Annealing with multi-point restarts
(popt4jlib.SA, after Ram–Sreenivas–Subramaniam [8]).

The Java class runs one chain per thread; here the chains are the rows of a
(P, D) array (vmapped; sharded by the engine). All four cooling schedules of
popt4jlib.SA.SAScheduleIntf are provided: linear, exponential, Boltzmann, Cauchy.
Fig.4 setup: linear schedule from T0=1000 down to 0 over the run.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function

Array = jax.Array

SCHEDULES = {
    "linear": lambda t, T0, n: T0 * jnp.maximum(1.0 - t / n, 0.0),
    "exponential": lambda t, T0, n: T0 * (0.99 ** t),
    "boltzmann": lambda t, T0, n: T0 / jnp.log(t + jnp.e),
    "cauchy": lambda t, T0, n: T0 / (1.0 + t),
}


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    schedule: str = "linear",
    T0: float = 1000.0,
    n_gens_hint: int = 10_000,   # horizon for the linear schedule
    step_frac: float = 0.1,      # proposal sigma as a fraction of the box width
) -> MetaHeuristic:
    """Simulated Annealing per-island policy (population of parallel chains)."""
    lo, hi = f.lo, f.hi
    sched = SCHEDULES[schedule]
    sigma = step_frac * (hi - lo)

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit, "t": jnp.zeros((), jnp.float32),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, fx, t = state["pop"], state["fit"], state["t"]
        kp, ka = jax.random.split(key)
        T = sched(t, T0, float(n_gens_hint))
        y = clip_box(x + sigma * jax.random.normal(kp, x.shape), lo, hi)
        fy = evaluator(y)
        dF = fy - fx
        u = jax.random.uniform(ka, fx.shape)
        accept = (dF <= 0) | (u < jnp.exp(-dF / jnp.maximum(T, 1e-12)))
        x = jnp.where(accept[:, None], y, x)
        fx = jnp.where(accept, fy, fx)
        i = jnp.argmin(fx)
        better = fx[i] < state["best_val"]
        return {
            "pop": x, "fit": fx, "t": t + 1.0,
            "best_val": jnp.where(better, fx[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    return MetaHeuristic("sa", init, gen, evals_per_gen=pop, init_evals=pop)
