"""DSA — multi-threaded Simulated Annealing with multi-point restarts
(popt4jlib.SA, after Ram–Sreenivas–Subramaniam [8]).

The Java class runs one chain per thread; here the chains are the rows of a
(P, D) array (vmapped; sharded by the engine). All four cooling schedules of
popt4jlib.SA.SAScheduleIntf are provided: linear, exponential, Boltzmann, Cauchy.
Fig.4 setup: linear schedule from T0=1000 down to 0 over the run.

``fused=True`` routes the evaluate-and-accept tail through the fused
``kernels.eval_select`` Pallas kernel via ``step_override``: the Metropolis
rule ``u < exp(-dF/T)`` is algebraically a per-row threshold test
``dF < -T*ln(u)``, which is exactly the kernel's acceptance form (greedy is
the ``thresh=0`` special case). Same key discipline as the XLA path.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.islands import MetaHeuristic, State, clip_box, uniform_init
from repro.functions.benchmarks import Function
from repro.kernels import registry as kreg
from repro.kernels.autotune import KernelConfig
from repro.kernels.eval_select import eval_select as _eval_select_kernel

Array = jax.Array

SCHEDULES = {
    "linear": lambda t, T0, n: T0 * jnp.maximum(1.0 - t / n, 0.0),
    "exponential": lambda t, T0, n: T0 * (0.99 ** t),
    "boltzmann": lambda t, T0, n: T0 / jnp.log(t + jnp.e),
    "cauchy": lambda t, T0, n: T0 / (1.0 + t),
}


def make(
    f: Function,
    evaluator: Callable[[Array], Array],
    pop: int,
    dim: int,
    schedule: str = "linear",
    T0: float = 1000.0,
    n_gens_hint: int = 10_000,   # horizon for the linear schedule
    step_frac: float = 0.1,      # proposal sigma as a fraction of the box width
    fused: bool = False,         # evaluate+accept in one Pallas kernel
    interpret: bool | None = None,
    kernel_cfg: KernelConfig | None = None,
) -> MetaHeuristic:
    """Simulated Annealing per-island policy (population of parallel chains)."""
    lo, hi = f.lo, f.hi
    sched = SCHEDULES[schedule]
    sigma = step_frac * (hi - lo)

    def init(key: Array) -> State:
        x = uniform_init(key, pop, dim, lo, hi)
        fit = evaluator(x)
        i = jnp.argmin(fit)
        return {
            "pop": x, "fit": fit, "t": jnp.zeros((), jnp.float32),
            "best_arg": x[i], "best_val": fit[i],
        }

    def gen(state: State, key: Array) -> State:
        x, fx, t = state["pop"], state["fit"], state["t"]
        kp, ka = jax.random.split(key)
        T = sched(t, T0, float(n_gens_hint))
        y = clip_box(x + sigma * jax.random.normal(kp, x.shape), lo, hi)
        fy = evaluator(y)
        dF = fy - fx
        u = jax.random.uniform(ka, fx.shape)
        accept = (dF <= 0) | (u < jnp.exp(-dF / jnp.maximum(T, 1e-12)))
        x = jnp.where(accept[:, None], y, x)
        fx = jnp.where(accept, fy, fx)
        i = jnp.argmin(fx)
        better = fx[i] < state["best_val"]
        return {
            "pop": x, "fit": fx, "t": t + 1.0,
            "best_val": jnp.where(better, fx[i], state["best_val"]),
            "best_arg": jnp.where(better, x[i], state["best_arg"]),
        }

    step_override = None
    if fused:
        spec = kreg.get_spec(f.name)   # KeyError if no kernel for this objective
        assert spec.fused_de, f.name

        def gen_fused(state: State, key: Array) -> State:
            x, fx, t = state["pop"], state["fit"], state["t"]
            kp, ka = jax.random.split(key)
            T = sched(t, T0, float(n_gens_hint))
            y = clip_box(x + sigma * jax.random.normal(kp, x.shape), lo, hi)
            u = jax.random.uniform(ka, fx.shape)
            # Metropolis as a threshold: u < exp(-dF/T)  <=>  dF < -T*ln(u)
            thresh = -jnp.maximum(T, 1e-12) * jnp.log(u)
            x, fx, _ = _eval_select_kernel(
                x, fx, y, thresh, fn=spec.eval_tag, shift=f.shift,
                bias=f.bias, interpret=interpret, kernel_cfg=kernel_cfg,
            )
            i = jnp.argmin(fx)
            better = fx[i] < state["best_val"]
            return {
                "pop": x, "fit": fx, "t": t + 1.0,
                "best_val": jnp.where(better, fx[i], state["best_val"]),
                "best_arg": jnp.where(better, x[i], state["best_arg"]),
            }

        step_override = gen_fused

    return MetaHeuristic("sa", init, gen, evals_per_gen=pop, init_evals=pop,
                         step_override=step_override)
