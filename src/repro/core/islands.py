"""Island-model engine — the unified runtime behind DGA/DDE/DPSO/DSA/DEA/DFA/DGABH/MCS.

Java design: one island per thread, migration over shared memory / sockets,
fitness evaluation optionally farmed to a worker network.

JAX design: islands are the leading axis of every state leaf, `vmap`-ed per
generation and sharded over the mesh's (pod, data) axes; migration is an
array roll/gather over that axis (lowers to collective-permute / all-gather);
the incumbent all-reduce at each sync round realizes the Observer pattern
between islands. One *sync round* = `sync_every` generations + migration +
incumbent merge.

Passing a ``core.mesh.MeshConfig`` makes the engine *device-parallel*
(DESIGN.md §8): the island axis is laid out over a 1-D device mesh and the
whole round scan runs under ``shard_map``, each shard owning
``n_islands / devices`` islands with its own EvalBackend instance. Ring
migration crosses shard boundaries as a single ``lax.ppermute`` exchange;
starvation and incumbent sharing degrade to all-gathers on the sync cadence.
A fixed seed on a 1-device mesh is bit-identical to the unsharded engine —
the determinism contract ``tests/test_distributed.py`` enforces.

The engine is *device-resident* by default: the whole run is one jitted
``lax.scan`` over sync rounds with donated state and an on-device
``(n_rounds,)`` incumbent-history buffer, and results cross to the host
exactly once at the end (DESIGN.md §4). Setting ``round_callback`` switches to
the host-stepped loop — one jit call per round — so the driver can checkpoint,
couple optimizers (ObserverHub), and survive restarts at round granularity.

``IslandConfig.polish`` turns any meta-heuristic into a *memetic hybrid*
(DESIGN.md §6): every ``polish_every`` rounds, each island's ``polish_topk``
best candidates pass through a batched fixed-shape local descent
(``optim.descent.make_polish`` — the paper's ``LocalOptimizerIntf``) inside
the same jitted scan, with polish evaluations charged to ``max_evals``. The
polish pass is deterministic, so fixed-seed trajectories stay reproducible
through both ``minimize`` and ``minimize_many``.

``IslandConfig.portfolio`` makes the engine *heterogeneous* (DESIGN.md §10):
each island carries its own policy from ``core.portfolio``'s unified-state
registry and the round loop dispatches the generation step through
``lax.switch`` over the portfolio's branch table — a mixed DE+PSO+SA island
set runs inside the SAME jitted scan, composing with migration (migrants
carry pos/fit; destination-policy aux slots re-initialize on adoption),
incumbent sharing, the polish cadence and island sharding. A homogeneous
portfolio skips the switch and is bit-identical to the plain
``algo_maker``-driven engine.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mesh as mesh_mod
from repro.core import migration as mig
from repro.core.api import OptimizeResult
from repro.core.executor import ExecutorConfig, make_batch_evaluator
from repro.core.mesh import MeshConfig
from repro.functions.benchmarks import Function

Array = jax.Array
State = dict[str, Array]


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    """Engine topology + budget: islands, migration, sharding and the hybrid
    memetic polish layer, all fixed before compilation (one shape-class)."""

    n_islands: int = 1
    pop: int = 64                 # per-island population capacity
    dim: int = 10
    sync_every: int = 10          # generations between migration/incumbent rounds
    migration: str = "ring"       # ring | starvation | none
    n_migrants: int = 2           # paper: at most 2 leave an island per round
    share_incumbent: bool = False # device-side Observer: broadcast global best
    max_evals: int = 100_000      # Fig.4 budget unit: function evaluations
    island_axes: tuple[str, ...] = ("data",)  # mesh axes the island dim shards over
    pop_axes: tuple[str, ...] | None = None   # mesh axes the population dim shards
                                              # over when n_islands == 1 (Table I)
    # Hybrid memetic layer (DESIGN.md §6): batched local-descent polish of each
    # island's top-k candidates, inside the jitted round scan. Polish evals are
    # charged to max_evals (see _budget), so hybrid and plain runs compare at
    # equal budgets — the paper's DGA+ASD-style configurations.
    polish: str = "none"          # none | asd | fcg | avd | bfgs
    polish_every: int = 1         # sync rounds between polish events
    polish_topk: int = 4          # per-island candidates polished per event
    polish_steps: int = 3         # descent iterations per polish event
    # Heterogeneous algorithm portfolio (DESIGN.md §10): one policy name per
    # island (cycled round-robin when shorter than n_islands). Non-empty
    # selects portfolio mode — pass algo_maker=None; per-policy params go in
    # IslandOptimizer(params={"de": {...}, ...}).
    portfolio: tuple[str, ...] = ()
    # Async staleness-bounded islands (DESIGN.md §13): "async" drops the
    # global round barrier — islands advance on their own cadence (an
    # AsyncSchedule) and exchange migrants through a fixed-shape mailbox ring
    # (core.migration.mailbox_*) instead of the lockstep exchange. Requires
    # migration in ("ring", "none"); with n_islands == 1 the mailbox is a
    # self-loop no-op and the engine runs the barrier path unchanged. An
    # all-ones schedule with max_staleness=0 degrades bit-identically to the
    # barrier engine (tests/test_async_islands.py).
    sync_policy: str = "barrier"  # barrier | async
    max_staleness: int = 0        # adopt migrants at most this many rounds old
    mailbox_slots: int = 4        # per-island mailbox ring capacity


@dataclasses.dataclass(frozen=True)
class MetaHeuristic:
    """One meta-heuristic = per-island init + generation step + eval accounting.

    ``step_override`` replaces ``gen`` inside the engine's round loop when set —
    the hook a fused whole-generation kernel (e.g. ``de.make(fused=True)``)
    uses to bypass the pluggable evaluator while keeping init, migration,
    incumbent sharing and budget accounting identical.
    """

    name: str
    init: Callable[[Array], State]          # key -> single-island state
    gen: Callable[[State, Array], State]    # (state, key) -> state
    evals_per_gen: int
    init_evals: int
    step_override: Callable[[State, Array], State] | None = None


@dataclasses.dataclass(frozen=True)
class AsyncSchedule:
    """Record/replay hook for the async engine's mailbox (DESIGN.md §13).

    ``step[t, i]`` — island ``i`` runs a sync round at tick ``t``;
    ``deliver[t, i]`` — the migrant batch island ``i`` posts at tick ``t``
    reaches its ring successor (False models a dropped message). Both
    default to all-ones — every island on every tick, every delivery on
    time — which is exactly the barrier cadence. A ``seed`` generates random
    Bernoulli masks instead (host-side numpy, so the jitted run only ever
    sees concrete arrays). Whatever arrays a run actually used are recorded
    in ``IslandOptimizer.recorded_schedule``; feeding that schedule back in
    replays the run bit-identically (the record/replay contract
    ``tests/test_async_islands.py`` enforces).
    """

    step: Any = None          # (n_rounds, n_islands) bool, or None
    deliver: Any = None       # (n_rounds, n_islands) bool, or None
    seed: int | None = None   # random masks when the arrays are absent
    step_prob: float = 0.75
    deliver_prob: float = 0.75

    def materialize(self, n_rounds: int, n_islands: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Concrete ``(step, deliver)`` bool masks of shape
        ``(n_rounds, n_islands)`` — explicit arrays are validated, missing
        ones are filled from ``seed`` (or all-ones without one)."""
        rng = np.random.RandomState(0 if self.seed is None else self.seed)

        def mask(a: Any, p: float, name: str) -> np.ndarray:
            if a is not None:
                a = np.asarray(a, dtype=bool)
                if a.shape != (n_rounds, n_islands):
                    raise ValueError(
                        f"AsyncSchedule.{name} has shape {a.shape}, engine "
                        f"needs {(n_rounds, n_islands)}")
                return a
            if self.seed is None:
                return np.ones((n_rounds, n_islands), dtype=bool)
            return rng.random_sample((n_rounds, n_islands)) < p

        return (mask(self.step, self.step_prob, "step"),
                mask(self.deliver, self.deliver_prob, "deliver"))

    @classmethod
    def from_cadences(cls, cadences, n_rounds: int) -> "AsyncSchedule":
        """Deterministic per-island cadence schedule: island ``i`` steps on
        ticks ``t`` with ``t % cadences[i] == 0`` (a straggler with cadence 4
        completes a round every 4th tick); every delivery fires."""
        c = np.asarray(cadences, dtype=int)
        if (c < 1).any():
            raise ValueError("cadences must be >= 1")
        step = (np.arange(n_rounds)[:, None] % c[None, :]) == 0
        return cls(step=step, deliver=np.ones_like(step))


AlgoMaker = Callable[..., MetaHeuristic]


def _accepts_kernel_cfg(maker: AlgoMaker) -> bool:
    """Whether a policy maker declares a ``kernel_cfg`` parameter (the hook
    the engine uses to thread ``ExecutorConfig.kernel`` into fused kernels).
    Custom makers without the parameter are simply not injected into."""
    import inspect
    try:
        return "kernel_cfg" in inspect.signature(maker).parameters
    except (TypeError, ValueError):      # builtins / odd callables
        return False


class IslandOptimizer:
    """popt4jlib OptimizerIntf over the island engine."""

    def __init__(
        self,
        algo_maker: AlgoMaker | None,
        cfg: IslandConfig,
        params: dict[str, Any] | None = None,
        mesh: Mesh | None = None,
        mesh_cfg: MeshConfig | None = None,
        exec_cfg: ExecutorConfig = ExecutorConfig(),
        round_callback: Callable[[int, Array, Array], None] | None = None,
        schedule: AsyncSchedule | None = None,
    ) -> None:
        self.algo_maker = algo_maker
        self.cfg = cfg
        self.params = dict(params or {})
        # Async staleness-bounded mode (DESIGN.md §13). With one island the
        # mailbox is a self-loop no-op, so the engine keeps the barrier path.
        if cfg.sync_policy not in ("barrier", "async"):
            raise ValueError(f"unknown sync_policy {cfg.sync_policy!r}")
        if cfg.sync_policy == "async" and cfg.migration == "starvation":
            raise ValueError(
                "async islands support ring|none migration only: starvation "
                "elects its host by a global argmin over every island's live "
                "count, which is inherently a barrier")
        if cfg.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if cfg.mailbox_slots < 1:
            raise ValueError("mailbox_slots must be >= 1")
        self._async = cfg.sync_policy == "async" and cfg.n_islands > 1
        if schedule is not None and not self._async:
            raise ValueError(
                "an AsyncSchedule needs sync_policy='async' and n_islands > 1")
        self.schedule = schedule
        # The schedule the last async run actually used (record side of the
        # record/replay contract); pass it back as ``schedule`` to replay.
        self.recorded_schedule: AsyncSchedule | None = None
        # High-water mark of adopted-migrant staleness in the last async run
        # (-1 = nothing adopted) — always <= cfg.max_staleness by construction.
        self.last_max_staleness: int | None = None
        # Heterogeneous portfolio mode (DESIGN.md §10): cfg.portfolio names
        # the per-island policies; the single algo_maker is unused.
        if cfg.portfolio:
            if algo_maker is not None:
                raise ValueError(
                    "cfg.portfolio selects per-island policies; pass "
                    "algo_maker=None")
            if cfg.n_islands <= 1:
                raise ValueError(
                    "cfg.portfolio requires n_islands > 1 — each island "
                    "carries one policy")
        elif algo_maker is None:
            raise ValueError("algo_maker is required unless cfg.portfolio is set")
        self.mesh = mesh
        self.mesh_cfg = mesh_cfg
        self.exec_cfg = exec_cfg
        self.round_callback = round_callback
        # Island sharding (DESIGN.md §8): a MeshConfig lays the island axis
        # over a 1-D device mesh and the round scan runs under shard_map.
        self._island_mesh = None
        self._axis: str | None = None
        self._n_shards = 1
        if mesh_cfg is not None:
            if mesh is not None:
                raise ValueError(
                    "mesh (population sharding) and mesh_cfg (island "
                    "sharding) are mutually exclusive")
            if cfg.n_islands <= 1:
                raise ValueError("island sharding requires n_islands > 1")
            mesh_cfg.local_islands(cfg.n_islands)   # divisibility check
            self._island_mesh = mesh_cfg.build()
            self._axis = mesh_cfg.axis
            self._n_shards = mesh_cfg.devices
        # Per-objective compiled multi-job runner (see minimize_many). Keyed by
        # objective identity so a scheduler holding one optimizer per bucket
        # reuses the jitted jobs-axis program across flushes.
        self._many_cache: dict[tuple, tuple[Any, Callable]] = {}

    # -- engine ------------------------------------------------------------

    def _evaluator(self, f: Function) -> Callable[[Array], Array]:
        """The engine's batch evaluator for ``f`` — memoized by
        ``make_batch_evaluator``, so every caller (generation steps via
        ``_build``, polish probes via ``_polish``) receives the SAME callable
        and therefore the same compiled xla/pallas path."""
        cfg = self.cfg
        pop_axis_shard = (
            self.mesh is not None and cfg.n_islands == 1 and cfg.pop_axes is not None
        )
        exec_cfg = dataclasses.replace(
            self.exec_cfg, mesh_axis=cfg.pop_axes if pop_axis_shard else None
        )
        return make_batch_evaluator(f, exec_cfg, self.mesh if pop_axis_shard else None)

    def _build(self, f: Function):
        """The per-run policy object: a ``MetaHeuristic`` from ``algo_maker``,
        or a ``core.portfolio.Portfolio`` in heterogeneous mode.

        ``ExecutorConfig.kernel`` is injected as ``kernel_cfg`` into every
        maker that declares the parameter (explicit per-policy params win), so
        one threaded :class:`~repro.kernels.autotune.KernelConfig` reaches the
        fused generation kernels and the pallas eval backend uniformly."""
        cfg = self.cfg
        if cfg.portfolio:
            from repro.core import portfolio as pf  # late: pf imports the algos
            return pf.build_portfolio(
                pf.expand(cfg.portfolio, cfg.n_islands), f=f,
                evaluator=self._evaluator(f), pop=cfg.pop, dim=cfg.dim,
                params=self.params, kernel_cfg=self.exec_cfg.kernel)
        kw = dict(self.params)
        if "kernel_cfg" not in kw and _accepts_kernel_cfg(self.algo_maker):
            kw["kernel_cfg"] = self.exec_cfg.kernel
        return self.algo_maker(
            f=f, evaluator=self._evaluator(f), pop=cfg.pop, dim=cfg.dim, **kw
        )

    def _eval_totals(self, algo) -> tuple[int, int]:
        """(per-generation, init) evaluation totals across all islands — the
        one place homogeneous and heterogeneous accounting meet."""
        if self.cfg.portfolio:
            return algo.per_gen_total, algo.init_total
        return (algo.evals_per_gen * self.cfg.n_islands,
                algo.init_evals * self.cfg.n_islands)

    def _round_fn(self, algo) -> Callable[[State, Array], State]:
        from repro.core import portfolio as pf  # late: pf imports the algos
        cfg = self.cfg
        port = algo if cfg.portfolio else None
        stacked = cfg.n_islands > 1
        axis, n_shards = self._axis, self._n_shards
        n_local = cfg.n_islands // n_shards
        if port is None:
            step = (algo.step_override if algo.step_override is not None
                    else algo.gen)

        def _local_branch() -> Array | None:
            # The (static, replicated) island->branch table; each shard takes
            # its block, mirroring the key-table slicing below.
            if port is None or port.n_branches == 1:
                return None
            br = jnp.asarray(port.branch_of)
            if axis is not None and n_shards > 1:
                br = _local_rows(br, axis, n_local)
            return br

        def round_fn(state: State, key: Array) -> State:
            br = _local_branch()

            def one_gen(carry: State, k: Array) -> tuple[State, None]:
                if stacked:
                    # Every shard derives the SAME global (I, 2) key table and
                    # takes its island block, so per-island key streams match
                    # the unsharded engine exactly (determinism contract, §8).
                    ks = jax.random.split(k, cfg.n_islands)
                    if axis is not None and n_shards > 1:
                        ks = _local_rows(ks, axis, n_local)
                    if port is not None:
                        return port.step_stacked(carry, ks, br), None
                    return jax.vmap(step)(carry, ks), None
                return step(carry, k), None

            gen_keys = jax.random.split(key, cfg.sync_every)
            state, _ = jax.lax.scan(one_gen, state, gen_keys)

            if stacked and cfg.migration != "none":
                old_pop, old_fit = state["pop"], state["fit"]
                if port is None:
                    mig_alive = state.get("alive")
                else:
                    # Per-island liveness for the (global) starvation count:
                    # policies that own an aging mask (ga) contribute it;
                    # the rest contribute isfinite(fit) — exactly what the
                    # plain engine's alive=None default computes, so a
                    # homogeneous portfolio stays bit-identical even when
                    # the executor has evicted candidates to +inf.
                    oa = jnp.asarray(port.owns_alive)
                    if axis is not None and n_shards > 1:
                        oa = _local_rows(oa, axis, n_local)
                    mig_alive = jnp.where(oa[:, None], state["alive"],
                                          jnp.isfinite(state["fit"]))
                pop, fit = mig.migrate(
                    cfg.migration, state["pop"], state["fit"],
                    k=cfg.n_migrants, alive=mig_alive,
                    axis=axis, n_shards=n_shards,
                )
                state = {**state, "pop": pop, "fit": fit}
                if port is not None or pf.has_adopt_state(algo.name):
                    # Migration carries pos/fit only; slots whose values
                    # changed hold adopted migrants. They revive (alive) and
                    # the destination policy re-initializes its aux slots
                    # (velocity, pbest, age, ... — DESIGN.md §10). The plain
                    # engine applies the same registered adopt rules to the
                    # native state, so homogeneous portfolios stay
                    # bit-identical to it for EVERY policy — and plain ga/pso
                    # no longer re-kill or mislead the migrants they adopt.
                    adopted = (jnp.any(pop != old_pop, axis=-1)
                               | (fit != old_fit))
                    if port is not None:
                        state = port.adopt_stacked(state, adopted, br)
                    else:
                        state = jax.vmap(partial(pf.adopt_native, algo.name))(
                            state, adopted)

            if stacked and cfg.share_incumbent:
                bv, ba = state["best_val"], state["best_arg"]
                if axis is not None and n_shards > 1:
                    # Device-side Observer across shards: gather every
                    # island's incumbent, broadcast the global best back.
                    gbv = jax.lax.all_gather(bv, axis, tiled=True)
                    gba = jax.lax.all_gather(ba, axis, tiled=True)
                else:
                    gbv, gba = bv, ba
                gi = jnp.argmin(gbv)
                state = {
                    **state,
                    "best_val": jnp.full_like(bv, gbv[gi]),
                    "best_arg": jnp.broadcast_to(gba[gi], ba.shape),
                }
            return state

        return round_fn

    def _async_round_fn(self, algo) -> Callable[[State, Array, Array, Array], State]:
        """The async sibling of :meth:`_round_fn` (DESIGN.md §13):
        ``(state, round_key, step_row, deliver_row) -> state``.

        The state carries the per-island mailbox leaves
        (``migration.MAILBOX_KEYS``) alongside the policy leaves. Each tick:
        islands selected by ``step_row`` run ``sync_every`` generations (the
        rest keep their exact old leaves — the same global key table is
        derived either way, so masked islands never perturb the key
        discipline); stepping islands post their best-k to their ring
        successor's mailbox gated by ``deliver_row`` and adopt the newest
        batch at most ``max_staleness`` rounds stale; per-island round
        counters advance by ``step_row``. With all-ones masks every op
        reduces to the barrier round body's values, which is the
        ``max_staleness=0`` degradation contract.
        """
        from repro.core import portfolio as pf  # late: pf imports the algos
        cfg = self.cfg
        port = algo if cfg.portfolio else None
        axis, n_shards = self._axis, self._n_shards
        n_local = cfg.n_islands // n_shards
        if port is None:
            step = (algo.step_override if algo.step_override is not None
                    else algo.gen)

        def local(x: Array) -> Array:
            if axis is not None and n_shards > 1:
                return _local_rows(x, axis, n_local)
            return x

        def round_fn(state: State, rk: Array, step_g: Array,
                     deliver_g: Array) -> State:
            br = None
            if port is not None and port.n_branches > 1:
                br = local(jnp.asarray(port.branch_of))
            step_row, deliver_row = local(step_g), local(deliver_g)
            policy = {k: v for k, v in state.items()
                      if k not in mig.MAILBOX_KEYS}
            box = {k: state[k] for k in mig.MAILBOX_KEYS}

            def one_gen(carry: State, k: Array) -> tuple[State, None]:
                ks = local(jax.random.split(k, cfg.n_islands))
                if port is not None:
                    return port.step_stacked(carry, ks, br), None
                return jax.vmap(step)(carry, ks), None

            gen_keys = jax.random.split(rk, cfg.sync_every)
            # The step mask is constant across a tick's generations, so it is
            # applied ONCE after the gens scan, never inside it: the inner
            # scan body stays HLO-identical to the barrier engine's, which is
            # what makes the max_staleness=0 degradation bit-exact (a select
            # inside the loop body changes XLA fusion of the policy
            # arithmetic and drifts pso by ulps). The select itself is pure
            # data movement — non-stepping islands keep their exact leaves.
            old_policy = policy
            policy, _ = jax.lax.scan(one_gen, policy, gen_keys)
            policy = jax.tree.map(
                lambda a, b: jnp.where(
                    step_row.reshape(step_row.shape + (1,) * (a.ndim - 1)),
                    a, b),
                policy, old_policy)

            if cfg.migration == "ring":
                old_pop, old_fit = policy["pop"], policy["fit"]
                box = mig.mailbox_post(
                    box, old_pop, old_fit, cfg.n_migrants,
                    step_row & deliver_row, axis=axis, n_shards=n_shards)
                pop, fit, box = mig.mailbox_adopt(
                    box, old_pop, old_fit, cfg.max_staleness, step_row)
                policy = {**policy, "pop": pop, "fit": fit}
                if port is not None or pf.has_adopt_state(algo.name):
                    # Same adopted-slot detection + aux re-init as the
                    # barrier round body (DESIGN.md §10).
                    adopted = (jnp.any(pop != old_pop, axis=-1)
                               | (fit != old_fit))
                    if port is not None:
                        policy = port.adopt_stacked(policy, adopted, br)
                    else:
                        policy = jax.vmap(partial(pf.adopt_native, algo.name))(
                            policy, adopted)

            if cfg.share_incumbent:
                bv, ba = policy["best_val"], policy["best_arg"]
                if axis is not None and n_shards > 1:
                    gbv = jax.lax.all_gather(bv, axis, tiled=True)
                    gba = jax.lax.all_gather(ba, axis, tiled=True)
                else:
                    gbv, gba = bv, ba
                gi = jnp.argmin(gbv)
                policy = {
                    **policy,
                    "best_val": jnp.full_like(bv, gbv[gi]),
                    "best_arg": jnp.broadcast_to(gba[gi], ba.shape),
                }

            box = {**box,
                   "round_ctr": box["round_ctr"] + step_row.astype(jnp.int32)}
            return {**policy, **box}

        return round_fn

    def _materialize_schedule(self, n_rounds: int
                              ) -> tuple[Array, Array]:
        """Concrete (step, deliver) masks for an async run, recording them in
        ``recorded_schedule`` — the record half of record/replay."""
        sched = self.schedule if self.schedule is not None else AsyncSchedule()
        step, deliver = sched.materialize(n_rounds, self.cfg.n_islands)
        self.recorded_schedule = AsyncSchedule(
            step=step, deliver=deliver, seed=sched.seed)
        return jnp.asarray(step), jnp.asarray(deliver)

    def _polish(self, f: Function) -> tuple[Callable[[State], State] | None, int]:
        """(state -> state polish pass, evals per polished point) — the hybrid
        memetic layer (DESIGN.md §6), or ``(None, 0)`` when ``cfg.polish`` is
        off. The pass takes each island's ``polish_topk`` best candidates
        through a fixed-shape batched local descent (``optim.descent
        .make_polish``) and writes improvements back into the population and
        the incumbent. It reuses the SAME cached evaluator as the generation
        steps (``make_batch_evaluator`` memoizes on objective + config), so
        polish probes hit the identical xla/pallas backend. Deterministic —
        no RNG — so it cannot perturb the engine's key chain.
        """
        cfg = self.cfg
        if cfg.polish == "none":
            return None, 0
        from repro.optim import descent  # late: optim.descent imports core.api

        pcfg = descent.PolishConfig(method=cfg.polish, steps=cfg.polish_steps)
        polish = descent.make_polish(f, self._evaluator(f), cfg.dim, pcfg)
        k = min(cfg.polish_topk, cfg.pop)

        def polish_island(state: State) -> State:
            pop, fit = state["pop"], state["fit"]
            _, idx = jax.lax.top_k(-fit, k)        # k best (smallest) fitness
            xs, fs = pop[idx], fit[idx]
            xs2, fs2 = polish(xs, fs)
            better = fs2 < fs                      # polish is monotone; guard anyway
            pop = pop.at[idx].set(jnp.where(better[:, None], xs2, xs))
            fit = fit.at[idx].set(jnp.where(better, fs2, fs))
            return track_best(state, pop, fit)

        pass_fn = jax.vmap(polish_island) if cfg.n_islands > 1 else polish_island
        return pass_fn, descent.polish_evals_per_point(cfg.dim, pcfg)

    def _scan_rounds(
        self, algo, polish_pass: Callable[[State], State] | None,
    ) -> Callable[[State, Array], tuple[State, Array]]:
        """Per-shard round scan ``(state, round_keys) -> (state, history)`` —
        the body both the unsharded run and the ``shard_map``-wrapped sharded
        run execute (polish on its cadence, per-round incumbent history)."""
        cfg = self.cfg
        stacked = cfg.n_islands > 1
        every = max(1, cfg.polish_every)
        axis, n_shards = self._axis, self._n_shards
        round_fn = self._round_fn(algo)

        def scan_rounds(state: State, round_keys: Array) -> tuple[State, Array]:
            def body(carry: State, xs: tuple[Array, Array]) -> tuple[State, Array]:
                rk, r = xs
                carry = round_fn(carry, rk)
                if polish_pass is not None:
                    carry = jax.lax.cond(
                        (r + 1) % every == 0, polish_pass, lambda s: s, carry)
                bv = carry["best_val"]
                point = jnp.min(bv) if stacked else bv
                if axis is not None and n_shards > 1:
                    point = jax.lax.pmin(point, axis)   # exact: min of mins
                return carry, point

            rs = jnp.arange(round_keys.shape[0])
            return jax.lax.scan(body, state, (round_keys, rs))

        return scan_rounds

    def _async_scan_rounds(
        self, algo, polish_pass: Callable[[State], State] | None,
    ) -> Callable[[State, Array, Array, Array], tuple[State, Array]]:
        """Async sibling of :meth:`_scan_rounds`: the schedule masks join the
        scan's per-tick inputs — ``(state, round_keys, step, deliver) ->
        (state, history)`` — so one compiled program serves every schedule."""
        cfg = self.cfg
        every = max(1, cfg.polish_every)
        axis, n_shards = self._axis, self._n_shards
        round_fn = self._async_round_fn(algo)

        def scan_rounds(state: State, round_keys: Array, step_m: Array,
                        deliver_m: Array) -> tuple[State, Array]:
            def body(carry: State, xs) -> tuple[State, Array]:
                rk, r, srow, drow = xs
                carry = round_fn(carry, rk, srow, drow)
                if polish_pass is not None:
                    carry = jax.lax.cond(
                        (r + 1) % every == 0, polish_pass, lambda s: s, carry)
                point = jnp.min(carry["best_val"])
                if axis is not None and n_shards > 1:
                    point = jax.lax.pmin(point, axis)
                return carry, point

            rs = jnp.arange(round_keys.shape[0])
            return jax.lax.scan(body, state, (round_keys, rs, step_m, deliver_m))

        return scan_rounds

    def _run_fn(
        self, algo, polish_pass: Callable[[State], State] | None = None,
    ) -> Callable[..., tuple]:
        """Whole-run device program: scan over sync rounds (polishing on the
        ``polish_every`` cadence), select the global incumbent on device,
        return ``(best_arg, best_val, history)``. With an island mesh the scan
        runs under ``shard_map`` (one shard per island block) and the final
        selection happens on the reassembled global state.

        The async engine's program additionally takes the schedule masks and
        returns the adopted-staleness high-water mark as a fourth output:
        ``(state, round_keys, step, deliver) -> (arg, val, history, stale)``.
        """
        stacked = self.cfg.n_islands > 1
        if self._async:
            scan_rounds = self._async_scan_rounds(algo, polish_pass)
            if self._island_mesh is None:
                body = scan_rounds
            else:
                in_specs, out_specs = mesh_mod.island_specs(self._axis, 3)
                body = mesh_mod.shard_map(
                    scan_rounds, self._island_mesh,
                    in_specs=in_specs, out_specs=out_specs)

            def run_async(state: State, round_keys: Array, step_m: Array,
                          deliver_m: Array):
                state, history = body(state, round_keys, step_m, deliver_m)
                arg, val = _select_best(state, stacked)
                return arg, val, history, jnp.max(state["stale_seen"])

            return run_async

        scan_rounds = self._scan_rounds(algo, polish_pass)
        if self._island_mesh is None:
            def run(state: State, round_keys: Array) -> tuple[Array, Array, Array]:
                state, history = scan_rounds(state, round_keys)
                arg, val = _select_best(state, stacked)
                return arg, val, history
            return run

        in_specs, out_specs = mesh_mod.island_specs(self._axis, 1)
        sharded = mesh_mod.shard_map(
            scan_rounds, self._island_mesh,
            in_specs=in_specs, out_specs=out_specs)

        def run(state: State, round_keys: Array) -> tuple[Array, Array, Array]:
            state, history = sharded(state, round_keys)
            arg, val = _select_best(state, stacked)
            return arg, val, history

        return run

    def _shard_state(self, state: State) -> State:
        if self._island_mesh is not None:
            spec = P(self._axis)
            return jax.tree.map(
                lambda x: jax.device_put(
                    x, NamedSharding(self._island_mesh, spec)), state)
        if self.mesh is None or self.cfg.n_islands <= 1:
            return state
        axes = self.cfg.island_axes

        def put(x: Array) -> Array:
            spec = P(axes, *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(self.mesh, spec))

        return jax.tree.map(put, state)

    def _init_state(self, algo, ik: Array) -> State:
        """Fresh engine state from init key ``ik`` — the one init rule every
        path (minimize, jobs axis, host stepper) shares. Async mode merges
        the mailbox leaves (``migration.mailbox_init``) into the state dict,
        so checkpointing and sharding see one pytree."""
        cfg = self.cfg
        if cfg.portfolio:
            state = algo.init_stacked(jax.random.split(ik, cfg.n_islands))
        elif cfg.n_islands > 1:
            state = jax.vmap(algo.init)(jax.random.split(ik, cfg.n_islands))
        else:
            state = algo.init(ik)
        if self._async:
            state = {**state, **mig.mailbox_init(
                cfg.n_islands, cfg.mailbox_slots, cfg.n_migrants, cfg.dim)}
        return state

    def _warm_fn(self, f: Function, algo) -> Callable[[State, Array, Array], State]:
        """``(state, warm (W, dim), warm_fit (W,)) -> state`` — immigration at
        init, the cross-host federation hop (``launch/federate.py``,
        DESIGN.md §13): adopt externally-routed candidates into island 0's
        worst slots through the same worst-k replacement rule migration uses,
        re-initializing destination-policy aux slots and refreshing the
        incumbent. Deterministic, so warm-started runs stay reproducible."""
        from repro.core import portfolio as pf  # late: pf imports the algos
        cfg = self.cfg
        port = algo if cfg.portfolio else None
        stacked = cfg.n_islands > 1

        def inject(state: State, w: Array, wf: Array) -> State:
            if stacked:
                old_pop, old_fit = state["pop"][0], state["fit"][0]
                pop0, fit0 = mig._replace_worst(old_pop, old_fit, w, wf)
                pop = state["pop"].at[0].set(pop0)
                fit = state["fit"].at[0].set(fit0)
                state = {**state, "pop": pop, "fit": fit}
                if port is not None or pf.has_adopt_state(algo.name):
                    changed = (jnp.any(pop0 != old_pop, axis=-1)
                               | (fit0 != old_fit))
                    adopted = (jnp.zeros(fit.shape, bool).at[0].set(changed))
                    if port is not None:
                        br = (jnp.asarray(port.branch_of)
                              if port.n_branches > 1 else None)
                        state = port.adopt_stacked(state, adopted, br)
                    else:
                        state = jax.vmap(partial(pf.adopt_native, algo.name))(
                            state, adopted)
                i = jnp.argmin(fit0)
                better = fit0[i] < state["best_val"][0]
                bv = state["best_val"].at[0].set(
                    jnp.where(better, fit0[i], state["best_val"][0]))
                ba = state["best_arg"].at[0].set(
                    jnp.where(better, pop0[i], state["best_arg"][0]))
                return {**state, "best_val": bv, "best_arg": ba}
            old_pop, old_fit = state["pop"], state["fit"]
            pop, fit = mig._replace_worst(old_pop, old_fit, w, wf)
            state = {**state, "pop": pop, "fit": fit}
            if pf.has_adopt_state(algo.name):
                changed = (jnp.any(pop != old_pop, axis=-1) | (fit != old_fit))
                state = pf.adopt_native(algo.name, state, changed)
            return track_best(state, pop, fit)

        return inject

    def _inject_warm(self, f: Function, algo, state: State, warm) -> State:
        """Host-side warm-start: evaluate the candidates with the run's own
        evaluator (same compiled backend as generation steps) and adopt them
        into the freshly-initialized state. Runs before sharding."""
        w = jnp.asarray(warm, jnp.float32)
        if w.ndim != 2 or w.shape[1] != self.cfg.dim:
            raise ValueError(
                f"warm candidates must have shape (W, {self.cfg.dim}), "
                f"got {w.shape}")
        wf = self._evaluator(f)(w)
        return self._warm_fn(f, algo)(state, w, wf)

    def _budget(self, per_gen_total: int, init_total: int,
                polish_per_point: int = 0) -> tuple[int, int, int, int]:
        """(n_rounds, per_round_evals, n_polish, per_polish_evals) from the
        eval budget — one accounting rule shared by minimize and
        minimize_many, fed by ``_eval_totals`` so heterogeneous portfolios
        (per-island ``evals_per_gen``) charge exactly what each island's
        policy consumes. Polish events fire every ``polish_every`` rounds and
        cost ``polish_topk * polish_per_point`` per island, charged against
        the same ``max_evals`` as generation steps, so hybrid runs stay
        budget-comparable with plain ones."""
        cfg = self.cfg
        per_round = per_gen_total * cfg.sync_every
        budget = cfg.max_evals - init_total
        if polish_per_point <= 0 or cfg.polish == "none":
            return max(1, budget // max(per_round, 1)), per_round, 0, 0
        # top-k is clamped to the island population in _polish; charge the same
        per_polish = polish_per_point * min(cfg.polish_topk, cfg.pop) * cfg.n_islands
        every = max(1, cfg.polish_every)

        def cost(n: int) -> int:
            return n * per_round + (n // every) * per_polish

        lo, hi = 1, max(1, budget // max(per_round, 1))
        while lo < hi:                      # largest n_rounds with cost <= budget
            mid = (lo + hi + 1) // 2
            if cost(mid) <= budget:
                lo = mid
            else:
                hi = mid - 1
        return lo, per_round, lo // every, per_polish

    def _single_fn(self, f: Function) -> tuple[Any, Callable, int]:
        """Cached (algo, jitted device-resident run, polish evals/point) for
        ``f`` — repeated ``minimize`` calls on one optimizer reuse the
        compiled program instead of re-tracing a fresh closure every call.
        Keyed by ``Function.cache_token()`` — a GC-stable identity token, so
        a recycled ``id()`` can never silently serve a stale program."""
        ck = ("single", *f.cache_token())
        hit = self._many_cache.get(ck)
        if hit is not None and hit[0] is f.fn:
            return hit[1], hit[2], hit[3]
        algo = self._build(f)
        polish_pass, pp = self._polish(f)
        run = jax.jit(self._run_fn(algo, polish_pass), donate_argnums=0)
        self._many_cache[ck] = (f.fn, algo, run, pp)
        return algo, run, pp

    def minimize(self, f: Function, key: Array,
                 warm: Any = None) -> OptimizeResult:
        """Run the full eval budget on objective ``f`` from PRNG ``key``.

        Device-resident (one jitted scan, one host transfer) unless
        ``round_callback`` is set; either path yields the same trajectory for
        a fixed key — including the polish cadence when ``cfg.polish`` is on.

        ``warm`` (optional, (W, dim)) are externally-routed immigrants —
        federation migrants — adopted into the initial population before
        round 0 (see :meth:`_warm_fn`).
        """
        cfg = self.cfg
        if self.round_callback is not None and self._island_mesh is not None:
            raise ValueError(
                "round_callback requires the unsharded engine — the "
                "host-stepped loop cannot run inside shard_map (DESIGN.md §8)")
        if self.round_callback is None:
            algo, run, pp = self._single_fn(f)
            polish_pass = None
        else:
            algo, run = self._build(f), None
            polish_pass, pp = self._polish(f)
        per_gen_total, init_total = self._eval_totals(algo)
        n_rounds, per_round, n_polish, per_polish = self._budget(
            per_gen_total, init_total, pp)

        key, ik = jax.random.split(key)
        state = self._init_state(algo, ik)
        if warm is not None and len(warm):
            state = self._inject_warm(f, algo, state, warm)
        state = self._shard_state(state)
        round_keys = _chain_split(key, n_rounds)
        if self._async:
            step_m, deliver_m = self._materialize_schedule(n_rounds)

        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            if self.round_callback is None:
                # Device-resident path: one jit, one host pull at the end.
                if self._async:
                    arg, val, history, stale = jax.device_get(
                        run(state, round_keys, step_m, deliver_m))
                    self.last_max_staleness = int(stale)
                else:
                    arg, val, history = jax.device_get(run(state, round_keys))
            else:
                # Host-stepped path: round granularity for checkpoint/coupling.
                # Polish applies on the same cadence, BEFORE the history/
                # callback read, mirroring the device-resident scan body.
                if self._async:
                    around = jax.jit(self._async_round_fn(algo),
                                     donate_argnums=0)
                    round_jit = lambda s, r: around(  # noqa: E731
                        s, round_keys[r], step_m[r], deliver_m[r])
                else:
                    brond = jax.jit(self._round_fn(algo), donate_argnums=0)
                    round_jit = lambda s, r: brond(s, round_keys[r])  # noqa: E731
                polish_jit = (jax.jit(polish_pass, donate_argnums=0)
                              if polish_pass is not None else None)
                every = max(1, cfg.polish_every)
                history = []
                for r in range(n_rounds):
                    state = round_jit(state, r)
                    if polish_jit is not None and (r + 1) % every == 0:
                        state = polish_jit(state)
                    bv = state["best_val"]
                    gval = jnp.min(bv) if cfg.n_islands > 1 else bv
                    history.append(float(gval))
                    self.round_callback(r, state["best_arg"], state["best_val"])
                if self._async:
                    self.last_max_staleness = int(
                        jnp.max(state["stale_seen"]))
                arg, val = _select_best(state, cfg.n_islands > 1)
                history = np.asarray(history, dtype=np.float32)

        n_evals = (init_total + n_rounds * per_round + n_polish * per_polish)
        return OptimizeResult(
            arg=arg, value=float(val), n_evals=n_evals,
            n_gens=n_rounds * cfg.sync_every, history=history,
        )

    def bucket_stepper(self, f: Function) -> "BucketStepper":
        """Cached host-stepped jobs-axis runner for objective ``f`` — the
        round-granular sibling of :meth:`minimize_many` (see
        :class:`BucketStepper`). Requires the unsharded engine: the
        host-stepped loop cannot run inside ``shard_map`` (DESIGN.md §8)."""
        ck = ("stepper", *f.cache_token())
        hit = self._many_cache.get(ck)
        if hit is not None and hit[0] is f.fn:
            return hit[1]
        stepper = BucketStepper(self, f)
        self._many_cache[ck] = (f.fn, stepper)
        return stepper

    # -- jobs axis ---------------------------------------------------------

    def _many_fn(self, f: Function) -> tuple[MetaHeuristic, Callable, int]:
        """Compiled jobs-axis runner for objective ``f``: ``keys (J, 2) ->
        (args (J, dim), vals (J,), histories (J, n_rounds))``, plus the
        polish evals/point for budget accounting.

        Each job replays ``minimize``'s exact device program — the same
        ``split``/``_chain_split`` key discipline, init, round scan and
        incumbent selection — so a job's trajectory is bit-identical to a
        standalone ``minimize`` call with the same key. ``vmap`` over jobs
        composes outside the per-island ``vmap`` and the executor's
        ``shard_map``: J same-shaped jobs cost one dispatch instead of J.

        With an island mesh the jobs-axis ``vmap`` moves *inside* the
        ``shard_map``: every shard initializes and steps its own island block
        for all J jobs, and only the final selection runs on the reassembled
        global state — the sharded analogue of the same program.
        """
        ck = ("many", *f.cache_token())
        hit = self._many_cache.get(ck)
        if hit is not None and hit[0] is f.fn:
            return hit[1], hit[2], hit[3]

        cfg = self.cfg
        algo = self._build(f)
        polish_pass, pp = self._polish(f)
        n_rounds, _, _, _ = self._budget(*self._eval_totals(algo), pp)
        stacked = cfg.n_islands > 1

        if self._async and self._island_mesh is None:
            # Async jobs axis: every job replays minimize's async program
            # under one shared (replicated) schedule; the masks are data, so
            # one compiled program serves every schedule of this length.
            run = self._run_fn(algo, polish_pass)

            def one_job_async(k: Array, step_m: Array, deliver_m: Array):
                key, ik = jax.random.split(k)
                state = self._init_state(algo, ik)
                return run(state, _chain_split(key, n_rounds),
                           step_m, deliver_m)

            many = jax.jit(jax.vmap(one_job_async, in_axes=(0, None, None)))
        elif self._async:
            axis, n_shards = self._axis, self._n_shards
            n_local = cfg.n_islands // n_shards
            scan_rounds = self._async_scan_rounds(algo, polish_pass)

            def one_job_local_async(k: Array, step_m: Array, deliver_m: Array):
                key, ik = jax.random.split(k)
                iks = jax.random.split(ik, cfg.n_islands)
                if n_shards > 1:
                    iks = _local_rows(iks, axis, n_local)
                if cfg.portfolio:
                    br = None
                    if algo.n_branches > 1:
                        br = jnp.asarray(algo.branch_of)
                        if n_shards > 1:
                            br = _local_rows(br, axis, n_local)
                    state = algo.init_stacked(iks, br)
                else:
                    state = jax.vmap(algo.init)(iks)
                state = {**state, **mig.mailbox_init(
                    n_local, cfg.mailbox_slots, cfg.n_migrants, cfg.dim)}
                return scan_rounds(state, _chain_split(key, n_rounds),
                                   step_m, deliver_m)

            sharded = mesh_mod.shard_map(
                jax.vmap(one_job_local_async, in_axes=(0, None, None)),
                self._island_mesh,
                in_specs=(P(), P(), P()), out_specs=(P(None, axis), P()))

            def many_sharded_async(keys: Array, step_m: Array,
                                   deliver_m: Array):
                state, hists = sharded(keys, step_m, deliver_m)
                args, vals = jax.vmap(lambda s: _select_best(s, True))(state)
                return args, vals, hists, jnp.max(state["stale_seen"])

            many = jax.jit(many_sharded_async)
        elif self._island_mesh is None:
            run = self._run_fn(algo, polish_pass)

            def one_job(k: Array) -> tuple[Array, Array, Array]:
                key, ik = jax.random.split(k)
                if cfg.portfolio:
                    state = algo.init_stacked(
                        jax.random.split(ik, cfg.n_islands))
                elif stacked:
                    state = jax.vmap(algo.init)(
                        jax.random.split(ik, cfg.n_islands))
                else:
                    state = algo.init(ik)
                return run(state, _chain_split(key, n_rounds))

            many = jax.jit(jax.vmap(one_job))
        else:
            axis, n_shards = self._axis, self._n_shards
            n_local = cfg.n_islands // n_shards
            scan_rounds = self._scan_rounds(algo, polish_pass)

            def one_job_local(k: Array) -> tuple[State, Array]:
                key, ik = jax.random.split(k)
                iks = jax.random.split(ik, cfg.n_islands)
                if n_shards > 1:
                    iks = _local_rows(iks, axis, n_local)
                if cfg.portfolio:
                    br = None
                    if algo.n_branches > 1:
                        br = jnp.asarray(algo.branch_of)
                        if n_shards > 1:
                            br = _local_rows(br, axis, n_local)
                    state = algo.init_stacked(iks, br)
                else:
                    state = jax.vmap(algo.init)(iks)
                return scan_rounds(state, _chain_split(key, n_rounds))

            sharded = mesh_mod.shard_map(
                jax.vmap(one_job_local), self._island_mesh,
                in_specs=(P(),), out_specs=(P(None, axis), P()))

            def many_sharded(keys: Array) -> tuple[Array, Array, Array]:
                state, hists = sharded(keys)        # (J, I, ...), (J, R)
                args, vals = jax.vmap(lambda s: _select_best(s, True))(state)
                return args, vals, hists

            many = jax.jit(many_sharded)
        self._many_cache[ck] = (f.fn, algo, many, pp)
        return algo, many, pp

    def minimize_many(self, f: Function, keys: Array) -> list[OptimizeResult]:
        """Run one job per row of ``keys (J, 2)`` in a single jitted dispatch.

        The scheduler's bucket-execution primitive: all jobs share this
        optimizer's config (one shape-class), differing only by PRNG key.
        When a mesh is attached the jobs axis is sharded over
        ``cfg.island_axes`` — the multi-job analogue of island sharding.
        """
        cfg = self.cfg
        if self.round_callback is not None:
            raise ValueError("minimize_many is device-resident only; "
                             "round_callback requires per-job minimize calls")
        algo, many, pp = self._many_fn(f)
        per_gen_total, init_total = self._eval_totals(algo)
        n_rounds, per_round, n_polish, per_polish = self._budget(
            per_gen_total, init_total, pp)

        keys = jnp.asarray(keys)
        n_jobs = keys.shape[0]
        if self.mesh is not None:
            # Bucket sizes are arbitrary (the service flushes whatever the
            # deadline window collected): pad the jobs axis to a multiple of
            # the sharding axis and slice the extras back off below.
            n_dev = 1
            for a in cfg.island_axes:
                n_dev *= self.mesh.shape[a]
            pad = (-n_jobs) % n_dev
            if pad:
                keys = jnp.concatenate(
                    [keys, jnp.broadcast_to(keys[:1], (pad, *keys.shape[1:]))])
            keys = jax.device_put(
                keys, NamedSharding(self.mesh, P(cfg.island_axes, None)))
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            if self._async:
                step_m, deliver_m = self._materialize_schedule(n_rounds)
                args, vals, hists, stale = jax.device_get(
                    many(keys, step_m, deliver_m))
                self.last_max_staleness = int(np.max(stale))
            else:
                args, vals, hists = jax.device_get(many(keys))

        n_evals = (init_total + n_rounds * per_round + n_polish * per_polish)
        return [
            OptimizeResult(
                arg=args[j], value=float(vals[j]), n_evals=n_evals,
                n_gens=n_rounds * cfg.sync_every, history=hists[j],
            )
            for j in range(n_jobs)
        ]


class BucketStepper:
    """Host-stepped jobs-axis runner — ``minimize_many``'s exact per-round
    program, advanced one sync round at a time from the host (DESIGN.md §12).

    The service layer's hardening primitive: because control returns to the
    host at every round boundary, a bucket run can stream per-round incumbent
    progress to pollers, honor cooperative cancellation, and snapshot its
    full engine state through ``checkpoint/store.py`` — while staying
    **bit-identical** to the device-resident ``minimize_many`` scan (same
    init, same ``_chain_split`` key streams, same round/polish/history order;
    the contract ``tests/test_service.py`` enforces).

    Requires the unsharded engine (no island mesh, no population mesh): the
    host-stepped loop cannot run inside ``shard_map``. Portfolio buckets are
    also refused: XLA compiles the ``lax.switch`` round body slightly
    differently per-round than inside the resident scan (last-ulp float
    drift), which would break the bit-identity contract — so the scheduler
    keeps those buckets on the device-resident path.
    """

    def __init__(self, opt: IslandOptimizer, f: Function) -> None:
        if opt._island_mesh is not None or opt.mesh is not None:
            raise ValueError(
                "bucket_stepper requires the unsharded engine — the "
                "host-stepped loop cannot run inside shard_map (DESIGN.md §8)")
        if opt.cfg.portfolio:
            raise ValueError(
                "bucket_stepper does not support portfolio islands: the "
                "per-round jit of the lax.switch body is not bit-identical "
                "to the resident scan's compilation of it (DESIGN.md §12)")
        cfg = opt.cfg
        self.cfg = cfg
        algo = opt._build(f)
        polish_pass, pp = opt._polish(f)
        per_gen_total, init_total = opt._eval_totals(algo)
        self.n_rounds, self.per_round, _, self.per_polish = opt._budget(
            per_gen_total, init_total, pp)
        self.init_evals = init_total
        self.every = max(1, cfg.polish_every)
        self.has_polish = polish_pass is not None
        stacked = cfg.n_islands > 1
        if opt._async:
            # Scheduler-driven async buckets run the deterministic barrier-
            # cadence schedule (all-ones masks, the AsyncSchedule default):
            # the resident async program under the default schedule computes
            # the same values, so the stepped-vs-resident bit-identity
            # contract (DESIGN.md §12) extends to async buckets.
            async_round = opt._async_round_fn(algo)
            ones = jnp.ones((cfg.n_islands,), bool)
            round_fn = lambda s, rk: async_round(s, rk, ones, ones)  # noqa: E731
        else:
            round_fn = opt._round_fn(algo)
        n_rounds = self.n_rounds
        # Warm-start immigration (launch/federate.py): jitted lazily on the
        # first bucket that actually carries warm candidates.
        self._warm_fn = opt._warm_fn(f, algo)
        self._warm_eval = opt._evaluator(f)
        self._inject_jit: Callable | None = None

        def prep(k: Array) -> tuple[State, Array]:
            # minimize_many's one_job preamble, verbatim: the same split/init/
            # _chain_split discipline, so trajectories match bit-for-bit.
            key, ik = jax.random.split(k)
            return opt._init_state(algo, ik), _chain_split(key, n_rounds)

        def keys_only(k: Array) -> Array:
            key, _ = jax.random.split(k)
            return _chain_split(key, n_rounds)

        def point(state: State) -> Array:
            bv = state["best_val"]
            return jnp.min(bv, axis=-1) if stacked else bv

        def step(state: State, rk: Array) -> tuple[State, Array]:
            state = jax.vmap(round_fn)(state, rk)
            return state, point(state)

        def step_polish(state: State, rk: Array) -> tuple[State, Array]:
            # Polish BEFORE the history point is read — the device-resident
            # scan body's order (round_fn -> cond polish -> point).
            state = jax.vmap(round_fn)(state, rk)
            state = jax.vmap(polish_pass)(state)
            return state, point(state)

        self._prep = jax.jit(jax.vmap(prep))
        self._keys = jax.jit(jax.vmap(keys_only))
        self._best = jax.jit(jax.vmap(lambda s: _select_best(s, stacked)))
        self._step = jax.jit(step, donate_argnums=0)
        self._step_polish = (jax.jit(step_polish, donate_argnums=0)
                             if self.has_polish else None)

    def init(self, keys: Array) -> tuple[State, Array]:
        """``keys (J, 2) -> (job-stacked state, round keys (J, n_rounds, 2))``
        — one jitted dispatch, identical to ``minimize_many``'s per-job init."""
        return self._prep(keys)

    def inject(self, state: State, warm) -> State:
        """Adopt warm-start immigrants (federation migrants, ``OptRequest
        .warm``) into every job's freshly-initialized state — the jobs-axis
        form of ``IslandOptimizer._warm_fn``. All jobs in a bucket share one
        warm batch (it is part of the shape-class), so the candidates are
        evaluated once and the adoption vmaps over jobs. Donates ``state``."""
        w = jnp.asarray(warm, jnp.float32)
        if w.ndim != 2 or w.shape[1] != self.cfg.dim:
            raise ValueError(
                f"warm candidates must have shape (W, {self.cfg.dim}), "
                f"got {w.shape}")
        if self._inject_jit is None:
            self._inject_jit = jax.jit(
                jax.vmap(self._warm_fn, in_axes=(0, None, None)),
                donate_argnums=0)
        return self._inject_jit(state, w, self._warm_eval(w))

    def round_keys(self, keys: Array) -> Array:
        """Re-derive the ``(J, n_rounds, 2)`` round-key table from job keys
        without re-running init — how a resumed run (which restores its state
        from a checkpoint) rebuilds the exact key stream it was killed on."""
        return self._keys(keys)

    def state_shape(self, keys: Array) -> State:
        """``ShapeDtypeStruct`` pytree of the job-stacked state — the
        ``like`` template a checkpoint restore validates shapes against."""
        return jax.eval_shape(lambda k: self._prep(k)[0], keys)

    def step(self, state: State, round_keys: Array, r: int) -> tuple[State, Array]:
        """Advance round ``r``: ``sync_every`` generations + migration +
        incumbent merge (+ polish on its cadence), returning the new state and
        each job's current global best value ``(J,)``. Donates ``state`` —
        callers must not reuse the argument after the call."""
        fn = (self._step_polish
              if self.has_polish and (r + 1) % self.every == 0 else self._step)
        return fn(state, round_keys[:, r])

    def best(self, state: State) -> tuple[Array, Array]:
        """Per-job global incumbent ``(args (J, dim), vals (J,))`` from the
        current state — non-donating, usable mid-run for partial results."""
        return self._best(state)

    def evals_done(self, rounds: int) -> int:
        """Per-job evaluations consumed after ``rounds`` completed rounds —
        the same accounting rule ``minimize_many`` charges at full budget."""
        n_polish = rounds // self.every if self.has_polish else 0
        return self.init_evals + rounds * self.per_round + n_polish * self.per_polish


def _local_rows(x: Array, axis: str, n_local: int) -> Array:
    """This shard's ``n_local``-row block of a replicated per-island table —
    how a shard under ``shard_map`` picks its islands' keys out of the global
    key table (same values the unsharded engine hands to ``vmap``)."""
    start = jax.lax.axis_index(axis) * n_local
    return jax.lax.dynamic_slice_in_dim(x, start, n_local, axis=0)


def _select_best(state: State, stacked: bool) -> tuple[Array, Array]:
    """Global incumbent from (possibly island-stacked) engine state — the one
    selection rule shared by the device-resident and host-stepped paths."""
    bv = state["best_val"]
    if stacked:
        gi = jnp.argmin(bv)
        return state["best_arg"][gi], bv[gi]
    return state["best_arg"], bv


@partial(jax.jit, static_argnums=1)
def _chain_split(key: Array, n: int) -> Array:
    """(n, 2) round keys from the sequential ``key, rk = split(key)`` chain —
    the same stream the engine's original host round loop drew, so trajectories
    are reproducible across the host-stepped and device-resident paths."""

    def body(k: Array, _: None) -> tuple[Array, Array]:
        ks = jax.random.split(k)
        return ks[0], ks[1]

    _, rks = jax.lax.scan(body, key, None, length=n)
    return rks


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def uniform_init(key: Array, pop: int, dim: int, lo: float, hi: float) -> Array:
    """Uniform-random (pop, dim) population in the box — the shared init."""
    return jax.random.uniform(key, (pop, dim), minval=lo, maxval=hi, dtype=jnp.float32)


def clip_box(x: Array, lo: float, hi: float) -> Array:
    """Project candidates back into the box domain (the paper's constraint)."""
    return jnp.clip(x, lo, hi)


def track_best(state: State, pop: Array, fit: Array) -> State:
    """Update the per-island incumbent from the current population."""
    i = jnp.argmin(fit)
    better = fit[i] < state["best_val"]
    return {
        **state,
        "pop": pop,
        "fit": fit,
        "best_val": jnp.where(better, fit[i], state["best_val"]),
        "best_arg": jnp.where(better, pop[i], state["best_arg"]),
    }
