"""Island-model engine — the unified runtime behind DGA/DDE/DPSO/DSA/DEA/DFA/DGABH/MCS.

Java design: one island per thread, migration over shared memory / sockets,
fitness evaluation optionally farmed to a worker network.

JAX design: islands are the leading axis of every state leaf, `vmap`-ed per
generation and sharded over the mesh's (pod, data) axes; migration is an
array roll/gather over that axis (lowers to collective-permute / all-gather);
the incumbent all-reduce at each sync round realizes the Observer pattern
between islands. One *sync round* = `sync_every` generations + migration +
incumbent merge.

The engine is *device-resident* by default: the whole run is one jitted
``lax.scan`` over sync rounds with donated state and an on-device
``(n_rounds,)`` incumbent-history buffer, and results cross to the host
exactly once at the end (DESIGN.md §4). Setting ``round_callback`` switches to
the host-stepped loop — one jit call per round — so the driver can checkpoint,
couple optimizers (ObserverHub), and survive restarts at round granularity.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import migration as mig
from repro.core.api import OptimizeResult
from repro.core.executor import ExecutorConfig, make_batch_evaluator
from repro.functions.benchmarks import Function

Array = jax.Array
State = dict[str, Array]


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    n_islands: int = 1
    pop: int = 64                 # per-island population capacity
    dim: int = 10
    sync_every: int = 10          # generations between migration/incumbent rounds
    migration: str = "ring"       # ring | starvation | none
    n_migrants: int = 2           # paper: at most 2 leave an island per round
    share_incumbent: bool = False # device-side Observer: broadcast global best
    max_evals: int = 100_000      # Fig.4 budget unit: function evaluations
    island_axes: tuple[str, ...] = ("data",)  # mesh axes the island dim shards over
    pop_axes: tuple[str, ...] | None = None   # mesh axes the population dim shards
                                              # over when n_islands == 1 (Table I)


@dataclasses.dataclass(frozen=True)
class MetaHeuristic:
    """One meta-heuristic = per-island init + generation step + eval accounting.

    ``step_override`` replaces ``gen`` inside the engine's round loop when set —
    the hook a fused whole-generation kernel (e.g. ``de.make(fused=True)``)
    uses to bypass the pluggable evaluator while keeping init, migration,
    incumbent sharing and budget accounting identical.
    """

    name: str
    init: Callable[[Array], State]          # key -> single-island state
    gen: Callable[[State, Array], State]    # (state, key) -> state
    evals_per_gen: int
    init_evals: int
    step_override: Callable[[State, Array], State] | None = None


AlgoMaker = Callable[..., MetaHeuristic]


class IslandOptimizer:
    """popt4jlib OptimizerIntf over the island engine."""

    def __init__(
        self,
        algo_maker: AlgoMaker,
        cfg: IslandConfig,
        params: dict[str, Any] | None = None,
        mesh: Mesh | None = None,
        exec_cfg: ExecutorConfig = ExecutorConfig(),
        round_callback: Callable[[int, Array, Array], None] | None = None,
    ) -> None:
        self.algo_maker = algo_maker
        self.cfg = cfg
        self.params = dict(params or {})
        self.mesh = mesh
        self.exec_cfg = exec_cfg
        self.round_callback = round_callback
        # Per-objective compiled multi-job runner (see minimize_many). Keyed by
        # objective identity so a scheduler holding one optimizer per bucket
        # reuses the jitted jobs-axis program across flushes.
        self._many_cache: dict[tuple, tuple[Any, Callable]] = {}

    # -- engine ------------------------------------------------------------

    def _build(self, f: Function) -> MetaHeuristic:
        cfg = self.cfg
        pop_axis_shard = (
            self.mesh is not None and cfg.n_islands == 1 and cfg.pop_axes is not None
        )
        exec_cfg = dataclasses.replace(
            self.exec_cfg, mesh_axis=cfg.pop_axes if pop_axis_shard else None
        )
        evaluator = make_batch_evaluator(f, exec_cfg, self.mesh if pop_axis_shard else None)
        return self.algo_maker(
            f=f, evaluator=evaluator, pop=cfg.pop, dim=cfg.dim, **self.params
        )

    def _round_fn(self, algo: MetaHeuristic) -> Callable[[State, Array], State]:
        cfg = self.cfg
        stacked = cfg.n_islands > 1
        step = algo.step_override if algo.step_override is not None else algo.gen

        def round_fn(state: State, key: Array) -> State:
            def one_gen(carry: State, k: Array) -> tuple[State, None]:
                if stacked:
                    ks = jax.random.split(k, cfg.n_islands)
                    return jax.vmap(step)(carry, ks), None
                return step(carry, k), None

            gen_keys = jax.random.split(key, cfg.sync_every)
            state, _ = jax.lax.scan(one_gen, state, gen_keys)

            if stacked and cfg.migration != "none":
                pop, fit = mig.migrate(
                    cfg.migration, state["pop"], state["fit"],
                    k=cfg.n_migrants, alive=state.get("alive"),
                )
                state = {**state, "pop": pop, "fit": fit}

            if stacked and cfg.share_incumbent:
                gi = jnp.argmin(state["best_val"])
                gval = state["best_val"][gi]
                garg = state["best_arg"][gi]
                state = {
                    **state,
                    "best_val": jnp.full_like(state["best_val"], gval),
                    "best_arg": jnp.broadcast_to(garg, state["best_arg"].shape),
                }
            return state

        return round_fn

    def _run_fn(self, algo: MetaHeuristic) -> Callable[[State, Array], tuple[Array, Array, Array]]:
        """Whole-run device program: scan over sync rounds, select the global
        incumbent on device, return ``(best_arg, best_val, history)``."""
        stacked = self.cfg.n_islands > 1
        round_fn = self._round_fn(algo)

        def run(state: State, round_keys: Array) -> tuple[Array, Array, Array]:
            def body(carry: State, rk: Array) -> tuple[State, Array]:
                carry = round_fn(carry, rk)
                bv = carry["best_val"]
                return carry, (jnp.min(bv) if stacked else bv)

            state, history = jax.lax.scan(body, state, round_keys)
            arg, val = _select_best(state, stacked)
            return arg, val, history

        return run

    def _shard_state(self, state: State) -> State:
        if self.mesh is None or self.cfg.n_islands <= 1:
            return state
        axes = self.cfg.island_axes

        def put(x: Array) -> Array:
            spec = P(axes, *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(self.mesh, spec))

        return jax.tree.map(put, state)

    def _budget(self, algo: MetaHeuristic) -> tuple[int, int]:
        """(n_rounds, per_round_evals) from the eval budget — one accounting
        rule shared by minimize and minimize_many."""
        cfg = self.cfg
        per_round = algo.evals_per_gen * cfg.n_islands * cfg.sync_every
        budget = cfg.max_evals - algo.init_evals * cfg.n_islands
        return max(1, budget // max(per_round, 1)), per_round

    def _single_fn(self, f: Function) -> tuple[MetaHeuristic, Callable]:
        """Cached (algo, jitted device-resident run) for ``f`` — repeated
        ``minimize`` calls on one optimizer reuse the compiled program instead
        of re-tracing a fresh closure every call."""
        ck = ("single", f.name, id(f.fn), id(f.shift), f.bias)
        hit = self._many_cache.get(ck)
        if hit is not None and hit[0] is f.fn:
            return hit[1], hit[2]
        algo = self._build(f)
        run = jax.jit(self._run_fn(algo), donate_argnums=0)
        self._many_cache[ck] = (f.fn, algo, run)
        return algo, run

    def minimize(self, f: Function, key: Array) -> OptimizeResult:
        cfg = self.cfg
        if self.round_callback is None:
            algo, run = self._single_fn(f)
        else:
            algo, run = self._build(f), None
        n_rounds, per_round = self._budget(algo)

        key, ik = jax.random.split(key)
        if cfg.n_islands > 1:
            init_keys = jax.random.split(ik, cfg.n_islands)
            state = jax.vmap(algo.init)(init_keys)
        else:
            state = algo.init(ik)
        state = self._shard_state(state)
        round_keys = _chain_split(key, n_rounds)

        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            if self.round_callback is None:
                # Device-resident path: one jit, one host pull at the end.
                arg, val, history = jax.device_get(run(state, round_keys))
            else:
                # Host-stepped path: round granularity for checkpoint/coupling.
                round_jit = jax.jit(self._round_fn(algo), donate_argnums=0)
                history = []
                for r in range(n_rounds):
                    state = round_jit(state, round_keys[r])
                    bv = state["best_val"]
                    gval = jnp.min(bv) if cfg.n_islands > 1 else bv
                    history.append(float(gval))
                    self.round_callback(r, state["best_arg"], state["best_val"])
                arg, val = _select_best(state, cfg.n_islands > 1)
                history = np.asarray(history, dtype=np.float32)

        n_evals = algo.init_evals * cfg.n_islands + n_rounds * per_round
        return OptimizeResult(
            arg=arg, value=float(val), n_evals=n_evals,
            n_gens=n_rounds * cfg.sync_every, history=history,
        )

    # -- jobs axis ---------------------------------------------------------

    def _many_fn(self, f: Function) -> tuple[MetaHeuristic, Callable]:
        """Compiled jobs-axis runner for objective ``f``: ``keys (J, 2) ->
        (args (J, dim), vals (J,), histories (J, n_rounds))``.

        Each job replays ``minimize``'s exact device program — the same
        ``split``/``_chain_split`` key discipline, init, round scan and
        incumbent selection — so a job's trajectory is bit-identical to a
        standalone ``minimize`` call with the same key. ``vmap`` over jobs
        composes outside the per-island ``vmap`` and the executor's
        ``shard_map``: J same-shaped jobs cost one dispatch instead of J.
        """
        ck = (f.name, id(f.fn), id(f.shift), f.bias)
        hit = self._many_cache.get(ck)
        if hit is not None and hit[0] is f.fn:
            return hit[1], hit[2]

        cfg = self.cfg
        algo = self._build(f)
        n_rounds, _ = self._budget(algo)
        run = self._run_fn(algo)
        stacked = cfg.n_islands > 1

        def one_job(k: Array) -> tuple[Array, Array, Array]:
            key, ik = jax.random.split(k)
            if stacked:
                state = jax.vmap(algo.init)(jax.random.split(ik, cfg.n_islands))
            else:
                state = algo.init(ik)
            return run(state, _chain_split(key, n_rounds))

        many = jax.jit(jax.vmap(one_job))
        self._many_cache[ck] = (f.fn, algo, many)
        return algo, many

    def minimize_many(self, f: Function, keys: Array) -> list[OptimizeResult]:
        """Run one job per row of ``keys (J, 2)`` in a single jitted dispatch.

        The scheduler's bucket-execution primitive: all jobs share this
        optimizer's config (one shape-class), differing only by PRNG key.
        When a mesh is attached the jobs axis is sharded over
        ``cfg.island_axes`` — the multi-job analogue of island sharding.
        """
        cfg = self.cfg
        if self.round_callback is not None:
            raise ValueError("minimize_many is device-resident only; "
                             "round_callback requires per-job minimize calls")
        algo, many = self._many_fn(f)
        n_rounds, per_round = self._budget(algo)

        keys = jnp.asarray(keys)
        n_jobs = keys.shape[0]
        if self.mesh is not None:
            # Bucket sizes are arbitrary (the service flushes whatever the
            # deadline window collected): pad the jobs axis to a multiple of
            # the sharding axis and slice the extras back off below.
            n_dev = 1
            for a in cfg.island_axes:
                n_dev *= self.mesh.shape[a]
            pad = (-n_jobs) % n_dev
            if pad:
                keys = jnp.concatenate(
                    [keys, jnp.broadcast_to(keys[:1], (pad, *keys.shape[1:]))])
            keys = jax.device_put(
                keys, NamedSharding(self.mesh, P(cfg.island_axes, None)))
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            args, vals, hists = jax.device_get(many(keys))

        n_evals = algo.init_evals * cfg.n_islands + n_rounds * per_round
        return [
            OptimizeResult(
                arg=args[j], value=float(vals[j]), n_evals=n_evals,
                n_gens=n_rounds * cfg.sync_every, history=hists[j],
            )
            for j in range(n_jobs)
        ]


def _select_best(state: State, stacked: bool) -> tuple[Array, Array]:
    """Global incumbent from (possibly island-stacked) engine state — the one
    selection rule shared by the device-resident and host-stepped paths."""
    bv = state["best_val"]
    if stacked:
        gi = jnp.argmin(bv)
        return state["best_arg"][gi], bv[gi]
    return state["best_arg"], bv


@partial(jax.jit, static_argnums=1)
def _chain_split(key: Array, n: int) -> Array:
    """(n, 2) round keys from the sequential ``key, rk = split(key)`` chain —
    the same stream the engine's original host round loop drew, so trajectories
    are reproducible across the host-stepped and device-resident paths."""

    def body(k: Array, _: None) -> tuple[Array, Array]:
        ks = jax.random.split(k)
        return ks[0], ks[1]

    _, rks = jax.lax.scan(body, key, None, length=n)
    return rks


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def uniform_init(key: Array, pop: int, dim: int, lo: float, hi: float) -> Array:
    return jax.random.uniform(key, (pop, dim), minval=lo, maxval=hi, dtype=jnp.float32)


def clip_box(x: Array, lo: float, hi: float) -> Array:
    return jnp.clip(x, lo, hi)


def track_best(state: State, pop: Array, fit: Array) -> State:
    """Update the per-island incumbent from the current population."""
    i = jnp.argmin(fit)
    better = fit[i] < state["best_val"]
    return {
        **state,
        "pop": pop,
        "fit": fit,
        "best_val": jnp.where(better, fit[i], state["best_val"]),
        "best_arg": jnp.where(better, pop[i], state["best_arg"]),
    }
