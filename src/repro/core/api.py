"""popt4jlib top-level API, JAX-native.

Java -> JAX mapping (see DESIGN.md §2):
  FunctionIntf.eval(arg, params)        -> functions.Function (pure jnp callable)
  OptimizerIntf.minimize(f)             -> Optimizer.minimize(f, key) -> OptimizeResult
  PairObjDouble                         -> OptimizeResult(arg, value, ...)
  setParams(HashMap) + OptimizerException -> frozen dataclass config per optimizer;
      JAX optimizers are pure functions, so the paper's "setParams while minimize()
      runs" race cannot exist — the config is immutable by construction.
  ObserverIntf/SubjectIntf              -> ObserverHub (host-side) + incumbent
      all-reduce at island sync rounds (device-side).
  PDBatchTaskExecutor network           -> pluggable EvalBackend layer
      (ExecutorConfig.backend = "xla" | "pallas" + kernels.registry; DESIGN.md §3)
      composed with shard_map population sharding.
  PDBTExecSingleCltWrkInitSrv server    -> OptRequest/OptResponse +
      core.scheduler.ShapeBucketScheduler + launch.opt_serve (DESIGN.md §5):
      many concurrent jobs packed into one jitted run per shape-class.
  GradientDescent.LocalOptimizerIntf    -> optim.descent: standalone multistart
      runs plus the batched polish layer (IslandConfig.polish /
      OptRequest.polish) that hybridizes any meta-heuristic in-scan
      (DESIGN.md §6), and core.pipeline for explore-then-polish staging.
  Fig.4 multi-method cooperation        -> IslandConfig.portfolio /
      OptRequest.portfolio (DESIGN.md §10): heterogeneous per-island policies
      from core.portfolio's unified-state registry, dispatched through
      lax.switch inside one jitted round scan.

Runs are device-resident by default: IslandOptimizer.minimize is one jitted
lax.scan over sync rounds, results cross to the host once (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

from repro.functions.benchmarks import Function

Array = jax.Array


@dataclasses.dataclass
class OptimizeResult:
    """popt4jlib ``PairObjDouble``: best argument + value, plus run accounting."""

    arg: Array                 # best argument found, shape (dim,)
    value: float               # f(arg)
    n_evals: int = 0           # function evaluations consumed (Fig. 4 budget unit)
    n_gens: int = 0
    history: Any = None        # optional per-sync-round incumbent trace


class Optimizer(Protocol):
    """popt4jlib ``OptimizerIntf``."""

    def minimize(self, f: Function, key: Array) -> OptimizeResult:
        """Minimize objective ``f`` from PRNG ``key``; pure and reproducible."""
        ...


# ---------------------------------------------------------------------------
# Multi-job service types — the popt4jlib ``PDBTExecSingleCltWrkInitSrv``
# client protocol as data (DESIGN.md §5). A client submits OptRequests; the
# scheduler buckets them by compiled shape-class and packs each bucket into a
# single jitted run with a leading jobs axis.
# ---------------------------------------------------------------------------

SHAPE_CLASS_FIELDS = (
    "fn", "algo", "dim", "pop", "n_islands", "sync_every", "migration",
    "n_migrants", "share_incumbent", "max_evals", "backend", "devices",
    "params", "polish", "polish_every", "polish_topk", "polish_steps",
    "portfolio", "sync_policy", "max_staleness", "warm",
)


def _freeze(v: Any) -> Any:
    """Recursively freeze JSON values into hashable form: dicts become sorted
    pair-tuples, lists become tuples — so nested per-policy portfolio params
    survive ``shape_class()``'s use as a dict key."""
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


@dataclasses.dataclass(frozen=True)
class OptRequest:
    """One optimization job — the JAX analogue of a Java ``TaskObject`` batch
    submitted to ``PDBatchTaskExecutorSrv``.

    Every field except ``seed`` participates in the compiled shape-class
    (:meth:`shape_class`): two requests that differ only by seed share one
    XLA program and run as rows of the same jobs axis.
    """

    fn: str                         # objective name in functions.FUNCTIONS
    algo: str = "de"                # key into core.ALGORITHMS
    dim: int = 10
    max_evals: int = 10_000         # Fig. 4 budget unit
    seed: int = 0
    pop: int = 64
    n_islands: int = 1
    sync_every: int = 10
    migration: str = "ring"
    n_migrants: int = 2
    share_incumbent: bool = False
    backend: str = "xla"            # ExecutorConfig.backend
    # Island sharding (DESIGN.md §8): devices the island axis is laid over
    # (core.mesh.MeshConfig). Part of the shape-class — the sharded program
    # (shard_map, ppermute ring, all-gather incumbent) is a different compiled
    # artifact, so sharded and single-device jobs never share a bucket.
    devices: int = 1
    params: tuple[tuple[str, Any], ...] = ()  # extra algo kwargs, hashable
    # Hybrid memetic layer (DESIGN.md §6). Polish parameters change the
    # compiled program (an extra in-scan polish stage, its top-k gather and
    # its cadence predicate), so they are part of the shape-class: hybrid and
    # plain requests never share a bucket.
    polish: str = "none"            # none | asd | fcg | avd | bfgs
    polish_every: int = 1           # sync rounds between polish events
    polish_topk: int = 4            # per-island candidates polished per event
    polish_steps: int = 3           # descent iterations per polish event
    # Heterogeneous algorithm portfolio (DESIGN.md §10): per-island policy
    # names (cycled when shorter than n_islands). Non-empty selects portfolio
    # mode — ``algo`` is ignored and ``params`` maps policy name -> kwargs.
    # Part of the shape-class: the portfolio's lax.switch branch table is
    # compiled into the program, so portfolio and homogeneous jobs (or two
    # different portfolios) never share a bucket.
    portfolio: tuple[str, ...] = ()
    # Async staleness-bounded islands (DESIGN.md §13): "barrier" is the
    # lockstep ppermute engine, "async" the per-island-cadence mailbox scan.
    # Both are part of the shape-class — the async program carries mailbox
    # state leaves and schedule-mask scan inputs the barrier one doesn't, and
    # max_staleness is compiled into the adoption predicate.
    sync_policy: str = "barrier"    # barrier | async
    max_staleness: int = 0          # adopt migrants at most this many rounds old
    # Warm-start immigrants — the cross-host federation hop
    # (launch/federate.py): candidate vectors adopted into island 0's worst
    # slots before round 0. Value-keyed into the shape-class, so every job in
    # a bucket shares one warm batch (the coordinator submits one job per
    # worker per leg, so this never fragments buckets in practice).
    warm: tuple[tuple[float, ...], ...] = ()

    def shape_class(self) -> tuple:
        """Bucket key: everything that feeds the compiled program's shape or
        its closed-over constants — i.e. everything but the seed. In
        portfolio mode ``algo`` is ignored by the engine, so it is
        normalized out of the key: portfolio jobs that differ only in the
        (unused) ``algo`` field share one compiled bucket."""
        return tuple(
            "" if n == "algo" and self.portfolio else getattr(self, n)
            for n in SHAPE_CLASS_FIELDS)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "OptRequest":
        d = dict(d)
        # JSON delivers dicts/lists; freeze both recursively so the request
        # stays hashable (shape_class is a dict key in the scheduler) —
        # including portfolio params' nested per-policy kwarg dicts.
        params = _freeze(d.pop("params", ()))
        if "portfolio" in d:
            d["portfolio"] = tuple(d["portfolio"])
        if "warm" in d:
            d["warm"] = tuple(
                tuple(float(x) for x in row) for row in d["warm"])
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown OptRequest fields: {sorted(unknown)}")
        return cls(params=params, **d)


@dataclasses.dataclass
class OptResponse:
    """Job envelope the service hands back on poll/result: lifecycle status,
    streamed per-round progress while the job's bucket is running
    (DESIGN.md §12), plus the ``OptimizeResult`` payload once it finishes.

    A ``cancelled`` job carries a *partial* result — the incumbent at the
    round boundary where cooperative preemption took effect."""

    job_id: str
    status: str = "queued"          # queued | running | done | error | cancelled
    result: OptimizeResult | None = None
    error: str | None = None
    # Streaming progress (host-stepped bucket runs update these every sync
    # round; pollers read them lock-free — each field is one GIL-atomic write)
    round: int | None = None        # sync rounds completed so far
    n_rounds: int | None = None     # total rounds this run will execute
    best_val: float | None = None   # current global incumbent value
    evals_done: int | None = None   # evaluations consumed so far

    def progress_dict(self) -> dict[str, Any]:
        """The streamed-progress fields that are set, as a JSON-able dict —
        what ``poll`` merges into its reply while the bucket is running."""
        out: dict[str, Any] = {}
        for k in ("round", "n_rounds", "best_val", "evals_done"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    def to_dict(self) -> dict[str, Any]:
        """JSONL-serializable reply for the service's result/poll ops."""
        out: dict[str, Any] = {"id": self.job_id, "status": self.status}
        if self.error is not None:
            out["error"] = self.error
        out.update(self.progress_dict())
        if self.result is not None:
            out.update(
                value=self.result.value,
                n_evals=self.result.n_evals,
                n_gens=self.result.n_gens,
                arg=[float(v) for v in jnp.asarray(self.result.arg).ravel()],
            )
        return out


class ObserverHub:
    """Observer design pattern (popt4jlib SubjectIntf/ObserverIntf).

    Device-side incumbent sharing between islands is a pmin collective inside the
    engine; *this* class is the host-side coupling between different optimizer
    processes (e.g. a DGA subject notifying an FCG local-search observer whenever a
    new incumbent appears — the paper's §IV.B coupling).
    """

    def __init__(self) -> None:
        self._observers: list[Callable[[Array, float], tuple[Array, float] | None]] = []
        self.best_arg: Array | None = None
        self.best_val: float = float("inf")

    def register(self, fn: Callable[[Array, float], tuple[Array, float] | None]) -> None:
        """Attach an observer; it may return a refined (arg, value) or None."""
        self._observers.append(fn)

    def notify(self, arg: Array, value: float) -> tuple[Array, float]:
        """Called by a subject when it finds a new incumbent. Observers may refine
        it (local search) and return an improved (arg, value)."""
        if value < self.best_val:
            self.best_arg, self.best_val = arg, float(value)
            for obs in self._observers:
                out = obs(arg, value)
                if out is not None and float(out[1]) < self.best_val:
                    self.best_arg, self.best_val = out[0], float(out[1])
        return self.best_arg, self.best_val


def lexi_min(val_a: Array, arg_a: Array, val_b: Array, arg_b: Array) -> tuple[Array, Array]:
    """(value, arg) pairwise min by value — the incumbent-merge primitive."""
    take_a = val_a <= val_b
    return jnp.where(take_a, val_a, val_b), jnp.where(take_a, arg_a, arg_b)
