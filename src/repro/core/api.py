"""popt4jlib top-level API, JAX-native.

Java -> JAX mapping (see DESIGN.md §2):
  FunctionIntf.eval(arg, params)        -> functions.Function (pure jnp callable)
  OptimizerIntf.minimize(f)             -> Optimizer.minimize(f, key) -> OptimizeResult
  PairObjDouble                         -> OptimizeResult(arg, value, ...)
  setParams(HashMap) + OptimizerException -> frozen dataclass config per optimizer;
      JAX optimizers are pure functions, so the paper's "setParams while minimize()
      runs" race cannot exist — the config is immutable by construction.
  ObserverIntf/SubjectIntf              -> ObserverHub (host-side) + incumbent
      all-reduce at island sync rounds (device-side).
  PDBatchTaskExecutor network           -> pluggable EvalBackend layer
      (ExecutorConfig.backend = "xla" | "pallas" + kernels.registry; DESIGN.md §3)
      composed with shard_map population sharding.

Runs are device-resident by default: IslandOptimizer.minimize is one jitted
lax.scan over sync rounds, results cross to the host once (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

from repro.functions.benchmarks import Function

Array = jax.Array


@dataclasses.dataclass
class OptimizeResult:
    """popt4jlib ``PairObjDouble``: best argument + value, plus run accounting."""

    arg: Array                 # best argument found, shape (dim,)
    value: float               # f(arg)
    n_evals: int = 0           # function evaluations consumed (Fig. 4 budget unit)
    n_gens: int = 0
    history: Any = None        # optional per-sync-round incumbent trace


class Optimizer(Protocol):
    """popt4jlib ``OptimizerIntf``."""

    def minimize(self, f: Function, key: Array) -> OptimizeResult: ...


class ObserverHub:
    """Observer design pattern (popt4jlib SubjectIntf/ObserverIntf).

    Device-side incumbent sharing between islands is a pmin collective inside the
    engine; *this* class is the host-side coupling between different optimizer
    processes (e.g. a DGA subject notifying an FCG local-search observer whenever a
    new incumbent appears — the paper's §IV.B coupling).
    """

    def __init__(self) -> None:
        self._observers: list[Callable[[Array, float], tuple[Array, float] | None]] = []
        self.best_arg: Array | None = None
        self.best_val: float = float("inf")

    def register(self, fn: Callable[[Array, float], tuple[Array, float] | None]) -> None:
        self._observers.append(fn)

    def notify(self, arg: Array, value: float) -> tuple[Array, float]:
        """Called by a subject when it finds a new incumbent. Observers may refine
        it (local search) and return an improved (arg, value)."""
        if value < self.best_val:
            self.best_arg, self.best_val = arg, float(value)
            for obs in self._observers:
                out = obs(arg, value)
                if out is not None and float(out[1]) < self.best_val:
                    self.best_arg, self.best_val = out[0], float(out[1])
        return self.best_arg, self.best_val


def lexi_min(val_a: Array, arg_a: Array, val_b: Array, arg_b: Array) -> tuple[Array, Array]:
    """(value, arg) pairwise min by value — the incumbent-merge primitive."""
    take_a = val_a <= val_b
    return jnp.where(take_a, val_a, val_b), jnp.where(take_a, arg_a, arg_b)
