"""Model assembly: parameter init, forward (train/prefill), decode step.

Layer stacking uses `lax.scan` over stacked parameters (one traced layer body
regardless of depth — essential for compiling 80+ dry-run programs on a CPU
host) with optional per-layer remat. Hybrid (zamba2) runs grouped: scans of
``shared_attn_every`` SSM layers interleaved with one weight-shared attention
block (13 applications for 81 layers).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.parallel import ctx

Array = jax.Array
Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn_layer(key: Array, cfg: ModelConfig) -> Params:
    ka, km, kn = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attn(ka, cfg),
        ("moe" if cfg.num_experts else "mlp"):
            (L.init_moe(km, cfg) if cfg.num_experts else L.init_mlp(km, cfg)),
    }
    if cfg.post_norm:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dt)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _init_ssm_layer(key: Array, cfg: ModelConfig) -> Params:
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "ssm": S.init_ssm(key, cfg),
    }


def init_params(key: Array, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {"final_norm": jnp.zeros((cfg.d_model,), dt)}

    if cfg.frontend != "audio_stub":
        params["embed"] = (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model), dt)
                           * (1.0 / jnp.sqrt(cfg.d_model)))
    if not cfg.tie_embeddings or cfg.frontend == "audio_stub":
        params["lm_head"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.padded_vocab), dt)
                             * (1.0 / jnp.sqrt(cfg.d_model)))
    if cfg.frontend != "none":
        params["frontend"] = {
            "proj": jax.random.normal(keys[2], (cfg.frontend_dim, cfg.d_model), dt)
                    * (1.0 / jnp.sqrt(cfg.frontend_dim)),
        }

    layer_keys = jax.random.split(keys[3], cfg.n_layers)
    if cfg.block_pattern == "attn":
        params["layers"] = jax.vmap(lambda k: _init_attn_layer(k, cfg))(layer_keys)
    else:
        params["layers"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg))(layer_keys)
        if cfg.block_pattern == "ssm+shared_attn":
            params["shared_attn"] = _init_attn_layer(keys[4], cfg)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_block(lp: Params, x: Array, cfg: ModelConfig, idx: Array,
                positions: Array, kv_cache=None, cache_pos=None):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, cache = L.attention(
        lp["attn"], h, cfg,
        layer_is_local=(idx % 2 == 0) if cfg.local_global_pattern else False,
        positions=positions, kv_cache=kv_cache, cache_pos=cache_pos)
    if cfg.post_norm:
        a = L.rmsnorm(a, lp["ln1_post"], cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        m, aux = L.moe(lp["moe"], h, cfg)
    else:
        m, aux = L.mlp(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    if cfg.post_norm:
        m = L.rmsnorm(m, lp["ln2_post"], cfg.norm_eps)
    return x + m, aux, cache


def _ssm_layer(lp: Params, x: Array, cfg: ModelConfig):
    return x + S.ssm_block(lp["ssm"], L.rmsnorm(x, lp["ln"], cfg.norm_eps), cfg)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, tokens: Array | None,
                 embeds: Array | None) -> Array:
    cd = jnp.dtype(cfg.compute_dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(cd) @ params["frontend"]["proj"].astype(cd))
    if tokens is not None:
        parts.append(params["embed"].astype(cd)[tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(cd)
    if cfg.pos_embedding == "sinusoidal":
        pos = L.sinusoidal_pos(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos[None].astype(cd)
    return x


def _head_logits(params: Params, cfg: ModelConfig, x: Array) -> Array:
    """LM-head matmul on (already final-normed) hidden states -> f32 logits."""
    cd = jnp.dtype(cfg.compute_dtype)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head.astype(cd)
    logits = L.softcap(logits, cfg.final_softcap)
    # mask vocab padding so the softmax distribution is over real tokens only
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, L.NEG_INF, logits.astype(jnp.float32))
    return logits.astype(jnp.float32)


def logits_from_hidden(params: Params, cfg: ModelConfig, x: Array) -> Array:
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _head_logits(params, cfg, x)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


@jax.custom_vjp
def _grad_safe_barrier(x: Array) -> Array:
    """``lax.optimization_barrier`` with a differentiation rule (identity VJP).

    The raw primitive has no JVP/VJP, so applying it inside a scanned layer
    block breaks ``grad``; this wrapper keeps the barrier on both the forward
    activations and the backward cotangents (the remat stash it protects is
    re-materialized in the backward loop too).
    """
    return jax.lax.optimization_barrier(x)


def _gsb_fwd(x: Array) -> tuple[Array, None]:
    return jax.lax.optimization_barrier(x), None


def _gsb_bwd(_, g: Array) -> tuple[Array]:
    return (jax.lax.optimization_barrier(g),)


_grad_safe_barrier.defvjp(_gsb_fwd, _gsb_bwd)


def _scan_layer_blocks(x: Array, layers: Params, idxs: Array,
                       block_fn, cfg: ModelConfig) -> tuple[Array, Array]:
    """scan over layers in checkpoint groups of ``remat_group``: one residual
    stash entry per group instead of per layer (the stash dominates training
    HBM at long sequence lengths)."""
    n = idxs.shape[0]
    G = cfg.remat_group if (cfg.remat and n % cfg.remat_group == 0) else 1

    if not cfg.scan_layers:
        def one_layer(lp, x, i):
            lp = ctx.constrain_layer_weights(lp)
            return block_fn(lp, x, jnp.asarray(i))

        if cfg.remat:
            one_layer = jax.checkpoint(one_layer, static_argnums=(2,))
        aux = jnp.zeros((), jnp.float32)
        for i in range(n):
            lp = jax.tree.map(lambda v: v[i], layers)
            x, a = one_layer(lp, x, i)
            aux = aux + a
        return x, aux

    def body(carry, inp):
        x, aux = carry
        lp_g, idx_g = inp
        # barrier: discourage XLA from hoisting upcasts of the remat stash out
        # of the backward loop (a 2x f32 copy of every saved layer input)
        x = _grad_safe_barrier(x)
        for j in range(G):
            lp = jax.tree.map(lambda v: v[j], lp_g)
            lp = ctx.constrain_layer_weights(lp)
            x, a = block_fn(lp, x, idx_g[j])
            aux = aux + a
        return (x, aux), None

    body = _maybe_remat(body, cfg)
    grouped = jax.tree.map(lambda v: v.reshape(n // G, G, *v.shape[1:]), layers)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (grouped, idxs.reshape(n // G, G)))
    return x, aux


def forward_hidden(params: Params, cfg: ModelConfig,
                   tokens: Array | None = None,
                   embeds: Array | None = None) -> tuple[Array, Array]:
    """Backbone forward -> (final-normed hidden (B, S, D), aux_loss)."""
    x = embed_inputs(params, cfg, tokens, embeds)
    Ssz = x.shape[1]
    positions = jnp.arange(Ssz)

    if cfg.block_pattern == "attn":
        def block(lp, x, idx):
            x, a, _ = _attn_block(lp, x, cfg, idx, positions)
            return x, a

        x, aux = _scan_layer_blocks(x, params["layers"],
                                    jnp.arange(cfg.n_layers), block, cfg)

    elif cfg.block_pattern == "ssm":
        def block(lp, x, idx):
            return _ssm_layer(lp, x, cfg), jnp.zeros((), jnp.float32)

        x, aux = _scan_layer_blocks(x, params["layers"],
                                    jnp.arange(cfg.n_layers), block, cfg)

    else:  # ssm+shared_attn (zamba2)
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every

        def block(lp, x, idx):
            return _ssm_layer(lp, x, cfg), jnp.zeros((), jnp.float32)

        sl = lambda tree, a, b: jax.tree.map(lambda v: v[a:b], tree)
        shared = params["shared_attn"]
        aux = jnp.zeros((), jnp.float32)

        # one checkpoint per (ssm group + shared attn application): 13 stash
        # entries for 81 layers instead of 81
        def group_fn(x, lps, g):
            import dataclasses
            inner = dataclasses.replace(cfg, remat=False)
            x, _ = _scan_layer_blocks(x, lps, jnp.arange(every), block, inner)
            x, a, _ = _attn_block(shared, x, cfg, jnp.asarray(g), positions)
            return x, a

        if cfg.remat:
            group_fn = jax.checkpoint(group_fn, static_argnums=(2,))
        for g in range(n_groups):
            x, a = group_fn(x, sl(params["layers"], g * every, (g + 1) * every), g)
            aux = aux + a
        if tail:
            x, _ = _scan_layer_blocks(
                x, sl(params["layers"], n_groups * every, cfg.n_layers),
                jnp.arange(tail), block, cfg)

    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def forward(params: Params, cfg: ModelConfig, tokens: Array | None = None,
            embeds: Array | None = None) -> tuple[Array, Array]:
    """Returns (logits (B, S, Vp) f32, aux_loss)."""
    x, aux = forward_hidden(params, cfg, tokens=tokens, embeds=embeds)
    return _head_logits(params, cfg, x), aux


def _ce_from_logits(logits: Array, labels: Array):
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - ll) * mask), jnp.sum(mask)


def loss_fn(params: Params, cfg: ModelConfig, batch: dict[str, Array]):
    """Next-token cross-entropy; label -100 positions are masked.

    The loss is computed in ``ce_chunks`` sequence chunks so that for
    256k-vocab archs the f32 logits (and their backward scatter) never
    materialize beyond (B, S/chunks, V) — the CE pipeline was the peak-memory
    bottleneck of every big-vocab train cell, not the layer stack."""
    labels = batch["labels"]
    n_chunks = cfg.ce_chunks if labels.shape[1] % max(cfg.ce_chunks, 1) == 0 else 1
    if n_chunks <= 1:
        logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
        tot, cnt = _ce_from_logits(logits, labels)
        ce = tot / jnp.maximum(cnt, 1.0)
        return ce + aux, {"ce": ce, "aux": aux}

    x, aux = forward_hidden(params, cfg, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"))
    B, S, D = x.shape
    C = S // n_chunks
    xc = x.reshape(B, n_chunks, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, C).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        xs, ls = inp
        logits = _head_logits(params, cfg, xs)
        t, c = _ce_from_logits(logits, ls)
        return (carry[0] + t, carry[1] + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc))
    ce = tot / jnp.maximum(cnt, 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with static caches
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Preallocated decode caches (ShapeDtypeStruct-compatible pytree)."""
    cd = jnp.dtype(cfg.compute_dtype)
    state: Params = {"pos": jnp.zeros((), jnp.int32)}
    kv, hd = cfg.n_kv_heads, cfg.hd
    if cfg.block_pattern == "attn":
        shape = (cfg.n_layers, batch, max_len, kv, hd)
        state["k"] = jnp.zeros(shape, cd)
        state["v"] = jnp.zeros(shape, cd)
    else:
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        state["conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), cd)
        state["ssd"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32)
        if cfg.block_pattern == "ssm+shared_attn":
            n_apps = cfg.n_layers // cfg.shared_attn_every
            state["k"] = jnp.zeros((n_apps, batch, max_len, kv, hd), cd)
            state["v"] = jnp.zeros((n_apps, batch, max_len, kv, hd), cd)
    return state


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                tokens: Array | None = None, embeds: Array | None = None):
    """One decode step: new token(s) (B, S) -> last-position logits (B, Vp),
    updated state. For the pure-attention pattern S may exceed 1 — the whole
    chunk is teacher-forced through the KV cache in one call (the batched
    prefill path); the recurrent patterns are single-token (S == 1).
    """
    x = embed_inputs(params, cfg, tokens, embeds)
    pos = state["pos"]
    Ssz = x.shape[1]
    if cfg.block_pattern != "attn" and Ssz != 1:
        raise ValueError(
            f"{cfg.block_pattern} decode_step is single-token (got S={Ssz}); "
            "use launch.steps.make_prefill_decode for multi-token prefill")
    positions = pos + jnp.arange(Ssz)  # query positions; causal vs cache arange

    if cfg.block_pattern == "attn":
        def body(carry, inp):
            x = carry
            lp, idx, kc, vc = inp
            x, _, (kc, vc) = _attn_block(lp, x, cfg, idx, positions,
                                         kv_cache=(kc, vc), cache_pos=pos)
            return x, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x,
            (params["layers"], jnp.arange(cfg.n_layers), state["k"], state["v"]))
        new_state = {**state, "pos": pos + Ssz, "k": k_new, "v": v_new}

    elif cfg.block_pattern == "ssm":
        def body(carry, inp):
            x = carry
            lp, conv, ssd = inp
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            y, conv, ssd = S.ssm_decode_step(lp["ssm"], h, cfg, conv, ssd)
            return x + y, (conv, ssd)

        x, (conv_new, ssd_new) = jax.lax.scan(
            body, x, (params["layers"], state["conv"], state["ssd"]))
        new_state = {**state, "pos": pos + 1, "conv": conv_new, "ssd": ssd_new}

    else:  # zamba2 hybrid
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        sl = lambda tree, a, b: jax.tree.map(lambda v: v[a:b], tree)
        shared = params["shared_attn"]
        convs, ssds, ks, vs = [], [], [], []

        def ssm_scan(x, lps, convs_g, ssds_g):
            def body(carry, inp):
                x = carry
                lp, conv, ssd = inp
                h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
                y, conv, ssd = S.ssm_decode_step(lp["ssm"], h, cfg, conv, ssd)
                return x + y, (conv, ssd)
            return jax.lax.scan(body, x, (lps, convs_g, ssds_g))

        for g in range(n_groups):
            lps = sl(params["layers"], g * every, (g + 1) * every)
            x, (c, s_) = ssm_scan(x, lps, state["conv"][g * every:(g + 1) * every],
                                  state["ssd"][g * every:(g + 1) * every])
            convs.append(c); ssds.append(s_)
            x, _, (kc, vc) = _attn_block(shared, x, cfg, jnp.asarray(g), positions,
                                         kv_cache=(state["k"][g], state["v"][g]),
                                         cache_pos=pos)
            ks.append(kc); vs.append(vc)
        if tail:
            lps = sl(params["layers"], n_groups * every, cfg.n_layers)
            x, (c, s_) = ssm_scan(x, lps, state["conv"][n_groups * every:],
                                  state["ssd"][n_groups * every:])
            convs.append(c); ssds.append(s_)
        new_state = {
            **state, "pos": pos + 1,
            "conv": jnp.concatenate(convs, axis=0),
            "ssd": jnp.concatenate(ssds, axis=0),
            "k": jnp.stack(ks), "v": jnp.stack(vs),
        }

    logits = logits_from_hidden(params, cfg, x)[:, -1]
    return logits, new_state


def prefill(params: Params, cfg: ModelConfig, tokens: Array | None = None,
            embeds: Array | None = None):
    """Prefill forward: returns last-position logits (the serving prefill step;
    cache write-back shares the forward path and is measured by the same cell)."""
    logits, _ = forward(params, cfg, tokens=tokens, embeds=embeds)
    return logits[:, -1]
