"""Unified model configuration covering all 10 assigned architectures.

One decoder-stack config expresses dense GQA transformers (llama/granite/gemma),
MoE (routed + shared experts), SSM (Mamba2/SSD), hybrids (zamba2: Mamba2 backbone
with a weight-shared attention block), and modality-stub frontends (VLM patch
embeddings / audio frame embeddings feed the backbone directly).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # dimensions
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    # block structure
    block_pattern: str = "attn"  # "attn" | "ssm" | "ssm+shared_attn"
    shared_attn_every: int = 6   # zamba2: shared attention block period
    # attention details
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"  # rope | sinusoidal (musicgen)
    window: int = 0              # sliding-window size; 0 = full attention
    local_global_pattern: bool = False  # gemma2: alternate local/global layers
    attn_softcap: float = 0.0    # gemma2: 50.0
    final_softcap: float = 0.0   # gemma2: 30.0
    qk_norm: bool = False
    post_norm: bool = False      # gemma2: sandwich (pre+post) block norms
    # MLP
    activation: str = "silu"     # silu (SwiGLU) | gelu (GeGLU)
    # MoE (num_experts == 0 -> dense MLP)
    num_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0            # per-expert hidden; 0 -> d_ff
    shared_expert_d_ff: int = 0  # qwen2-moe: 4 shared experts fused into one FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 16         # dispatch groups (= data shards): routing,
                                 # rank-cumsum and capacity buffers are built
                                 # per group so the scatter stays shard-local
                                 # (a global scatter makes GSPMD replicate +
                                 # all-reduce the whole dispatch buffer)
    # SSM (Mamba2 / SSD)
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # embeddings / head
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma: x * sqrt(d_model)
    vocab_pad_to: int = 256
    # frontend stub
    frontend: str = "none"       # none | vlm_stub | audio_stub
    frontend_dim: int = 0        # precomputed patch/frame embedding width
    frontend_len: int = 0        # number of prefix embedding positions (vlm)
    # numerics
    norm_eps: float = 1e-6
    ce_chunks: int = 8           # sequence chunks for the CE loss (big-vocab
                                 # archs: logits never materialize beyond S/chunks)
    attn_direct_max: int = 2048  # S above this -> chunked online-softmax attention
    attn_kv_block: int = 1024    # KV block for the chunked path
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    remat_group: int = 2         # layers per checkpoint block (stash / group)
    scan_layers: bool = True     # False: unroll the layer loop. Required with
                                 # FSDP: GSPMD rewrites gather(slice(xs)) ->
                                 # slice(gather(xs)) and hoists the full-stack
                                 # all-gather out of a scan; straight-line code
                                 # gathers one layer at a time.
    # sharding mode: "tp" (weights replicated over data) or "tp+fsdp"
    # (master weights/moments additionally sharded over the data axis)
    sharding_mode: str = "tp"
    # training
    seq_len: int = 512
    global_batch: int = 8

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def block_kinds(self) -> list[str]:
        if self.block_pattern == "attn":
            return ["attn"] * self.n_layers
        if self.block_pattern == "ssm":
            return ["ssm"] * self.n_layers
        if self.block_pattern == "ssm+shared_attn":
            return ["ssm"] * self.n_layers  # shared attn is interleaved, not a layer
        raise ValueError(self.block_pattern)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2 if self.block_pattern == "attn" else 3,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16 if self.head_dim else 0,
            d_ff=128,
            vocab=503,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            moe_groups=1,
            moe_d_ff=32 if self.num_experts else 0,
            shared_expert_d_ff=64 if self.shared_expert_d_ff else 0,
            ssm_state=16,
            ssm_head_dim=16,
            ssm_chunk=16,
            shared_attn_every=2,
            window=8 if self.window else 0,
            frontend_dim=32 if self.frontend != "none" else 0,
            frontend_len=4 if self.frontend == "vlm_stub" else 0,
            seq_len=32,
            global_batch=2,
            remat=False,
            compute_dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# hardware model for roofline math (TPU v5e-like, per assignment constants)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
