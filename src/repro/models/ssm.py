"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: the sequence is split into chunks of length Q; within a
chunk the output is a masked (decay-weighted) attention-like matmul — MXU
friendly — and across chunks a small recurrent state (H, N, P) is carried by a
scan. This is the TPU-native adaptation: the GPU implementation's fused Triton
scan becomes (a) this matmul-dominant chunked form and (b) the Pallas kernel in
kernels/ssd_scan.py for the inner recurrence.

Projections are SPLIT per component (z, x, B, C, dt) rather than fused as in
the CUDA reference: the x/z/dt outputs are head-aligned so tensor parallelism
shards heads over the `model` axis without slicing through component
boundaries; B/C (the small state projections) stay replicated.

Decode is the O(1) recurrent form: state <- state * exp(dt*A) + dt * B outer x.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jax.Array
Params = dict[str, Any]


def init_ssm(key: Array, cfg: ModelConfig) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    s = 1.0 / jnp.sqrt(d)
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dt) * s,
        "w_x": jax.random.normal(ks[1], (d, di), dt) * s,
        "w_B": jax.random.normal(ks[2], (d, n), dt) * s,
        "w_C": jax.random.normal(ks[3], (d, n), dt) * s,
        "w_dt": jax.random.normal(ks[4], (d, h), dt) * s,
        "conv_x": jax.random.normal(ks[5], (cfg.ssm_conv, di), dt) * 0.5,
        "conv_B": jax.random.normal(ks[6], (cfg.ssm_conv, n), dt) * 0.5,
        "conv_C": jax.random.normal(ks[7], (cfg.ssm_conv, n), dt) * 0.5,
        "conv_bias_x": jnp.zeros((di,), dt),
        "conv_bias_B": jnp.zeros((n,), dt),
        "conv_bias_C": jnp.zeros((n,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)).astype(dt),
        "D": jnp.ones((h,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))).astype(dt),
        "norm_scale": jnp.zeros((di,), dt),
        "w_out": jax.random.normal(key, (di, d), dt) * (1.0 / jnp.sqrt(di)),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None = None):
    """Depthwise causal conv, kernel (K, C), x (B, S, C). Returns (y, new_state)
    where state is the last K-1 inputs (decode cache)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b
    return jax.nn.silu(y), xp[:, -(K - 1):, :]


def _ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array, Q: int):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs per head; dt: (B, S, H) positive step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B, S, N) (single group).
    Returns y: (B, S, H, P).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nC = S // Q
    f32 = jnp.float32

    xc = xh.reshape(Bsz, nC, Q, H, P)
    dtc = dt.reshape(Bsz, nC, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nC, Q, N)
    Cc = Cm.reshape(Bsz, nC, Q, N)

    dA = dtc * A[None, None, None, :]                  # (B, nC, Q, H), negative
    seg = jnp.cumsum(dA, axis=2)                       # within-chunk cumulative
    total = seg[:, :, -1, :]                           # (B, nC, H)

    # decay matrices L[i,j] = exp(seg_i - seg_j) for i >= j
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nC,Q,Q,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(li), 0.0)

    xdt = xc * dtc[..., None].astype(xh.dtype)         # dt-scaled input

    # intra-chunk: Y = (C B^T * L) @ (x dt)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc).astype(f32)  # (B,nC,Q,Q)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp",
                         (cb[..., None] * L).astype(xh.dtype), xdt)

    # chunk-final states: S_c = sum_j exp(total - seg_j) B_j (x dt)_j
    decay_to_end = jnp.exp(total[:, :, None, :] - seg)  # (B,nC,Q,H)
    sb = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                    Bc, decay_to_end.astype(xh.dtype), xdt)  # (B,nC,H,N,P)

    # inter-chunk recurrence over chunk index
    def body(state, inp):
        sb_c, total_c, Cc_c, seg_c = inp
        yprev = jnp.einsum("bqn,bqh,bhnp->bqhp",
                           Cc_c, jnp.exp(seg_c).astype(Cc_c.dtype),
                           state.astype(Cc_c.dtype))
        state = state * jnp.exp(total_c)[:, :, None, None] + sb_c.astype(f32)
        return state, yprev

    state0 = jnp.zeros((Bsz, H, N, P), f32)
    xs = (sb.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2),
          Cc.transpose(1, 0, 2, 3), seg.transpose(1, 0, 2, 3))
    _, yprev = jax.lax.scan(body, state0, xs)
    y = y_intra + yprev.transpose(1, 0, 2, 3, 4).astype(y_intra.dtype)
    return y.reshape(Bsz, S, H, P)


def _gated_norm_out(params: Params, y: Array, z: Array, cfg: ModelConfig) -> Array:
    cd = jnp.dtype(cfg.compute_dtype)
    y = (y * jax.nn.silu(z)).astype(cd)
    # f32 bridge after square: keeps the activation cotangent in bf16
    var = jnp.mean(jnp.square(y).astype(jnp.float32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + cfg.norm_eps).astype(cd)
    y = (y * inv) * (1.0 + params["norm_scale"].astype(cd))
    return y @ params["w_out"].astype(cd)


def ssm_block(params: Params, x: Array, cfg: ModelConfig) -> Array:
    """Training/prefill forward. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    z = x @ params["w_z"].astype(cd)
    xi = x @ params["w_x"].astype(cd)
    Bm = x @ params["w_B"].astype(cd)
    Cm = x @ params["w_C"].astype(cd)
    dt = jax.nn.softplus((x @ params["w_dt"].astype(cd)).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    xi, _ = _causal_conv(xi, params["conv_x"].astype(cd),
                         params["conv_bias_x"].astype(cd))
    Bm, _ = _causal_conv(Bm, params["conv_B"].astype(cd),
                         params["conv_bias_B"].astype(cd))
    Cm, _ = _causal_conv(Cm, params["conv_C"].astype(cd),
                         params["conv_bias_C"].astype(cd))
    xi = xi.reshape(B, S, h, p)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y = _ssd_chunked(xi, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
    y = y + params["D"].astype(cd)[None, None, :, None] * xi
    return _gated_norm_out(params, y.reshape(B, S, di), z, cfg)


def ssm_decode_step(params: Params, x: Array, cfg: ModelConfig,
                    conv_state: Array, ssd_state: Array):
    """Single-token recurrent step. x: (B, 1, D).
    conv_state: (B, K-1, di + 2N); ssd_state: (B, H, N, P) f32."""
    B = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    z = x @ params["w_z"].astype(cd)
    xi = x @ params["w_x"].astype(cd)
    Bm = x @ params["w_B"].astype(cd)
    Cm = x @ params["w_C"].astype(cd)
    dt = jax.nn.softplus((x @ params["w_dt"].astype(cd)).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    cs_x, cs_B, cs_C = (conv_state[..., :di], conv_state[..., di:di + n],
                        conv_state[..., di + n:])
    xi, cs_x = _causal_conv(xi, params["conv_x"].astype(cd),
                            params["conv_bias_x"].astype(cd), cs_x)
    Bm, cs_B = _causal_conv(Bm, params["conv_B"].astype(cd),
                            params["conv_bias_B"].astype(cd), cs_B)
    Cm, cs_C = _causal_conv(Cm, params["conv_C"].astype(cd),
                            params["conv_bias_C"].astype(cd), cs_C)
    conv_state = jnp.concatenate(
        [cs_x.astype(cd), cs_B.astype(cd), cs_C.astype(cd)], axis=-1)

    xi = xi.reshape(B, h, p)
    Bm1, Cm1 = Bm[:, 0], Cm[:, 0]                      # (B, N)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                     # (B, H)
    dA = jnp.exp(dt1 * A[None, :])
    upd = jnp.einsum("bn,bhp->bhnp", Bm1.astype(jnp.float32),
                     (xi * dt1[..., None].astype(cd)).astype(jnp.float32))
    ssd_state = ssd_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhnp,bn->bhp", ssd_state, Cm1.astype(jnp.float32)).astype(cd)
    y = y + params["D"].astype(cd)[None, :, None] * xi
    return _gated_norm_out(params, y.reshape(B, 1, di), z, cfg), conv_state, ssd_state
