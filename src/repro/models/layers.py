"""Transformer building blocks: RMSNorm, RoPE, GQA attention (direct + chunked
online-softmax for long context), dense MLP, MoE with scatter dispatch.

All functions are pure; parameters are nested dicts of jnp arrays. Activation
compute is in ``cfg.compute_dtype`` (bf16 on TPU), reductions in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel import ctx

Array = jax.Array
Params = dict[str, Any]

NEG_INF = -1e9  # mask bias (bf16-safe)


# ---------------------------------------------------------------------------
# norms / positions
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float) -> Array:
    # variance accumulates in f32, but the data path stays in x.dtype. The
    # f32 bridge sits AFTER square(x): its transpose converts the cotangent
    # back to bf16 before it touches x — without this, dL/dx is promoted to
    # f32 through the whole backward pass (2x stash memory, 2x collective
    # bytes, and ~40 GB of f32 activation params in the grad fusions).
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x * inv) * (1.0 + scale.astype(x.dtype))


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: Array, cap: float) -> Array:
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attn(key: Array, cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = lambda *sh: 1.0 / jnp.sqrt(sh[0])
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": (jax.random.normal(k1, (d, h * hd), dt) * s(d)),
        "wk": (jax.random.normal(k2, (d, kv * hd), dt) * s(d)),
        "wv": (jax.random.normal(k3, (d, kv * hd), dt) * s(d)),
        "wo": (jax.random.normal(k4, (h * hd, d), dt) * s(h * hd)),
    }


def _mask_bias(q_pos: Array, k_pos: Array, window: Array | int) -> Array:
    """Causal (+ optional sliding window) bias computed from positions — never
    materializes beyond the current (q_block, k_block) tile. ``window`` may be a
    traced scalar (gemma2 alternates local/global inside scan-over-layers); 0 or
    negative means full causal attention."""
    delta = q_pos[:, None] - k_pos[None, :]
    causal = delta >= 0
    win_ok = jnp.where(jnp.asarray(window) > 0, delta < jnp.asarray(window), True)
    return jnp.where(causal & win_ok, 0.0, NEG_INF).astype(jnp.float32)


def _repeat_kv(k: Array, rep: int) -> Array:
    """(B, T, KV, hd) -> (B, T, KV*rep, hd). KV heads are expanded to the full
    head count *before* the einsums: the (KV, rep) factorization of a
    model-sharded head axis does not partition (KV < mesh size for most GQA
    archs), while the expanded H axis does."""
    return k if rep == 1 else jnp.repeat(k, rep, axis=2)


def _attend_direct_g(q, k, v, q_pos, k_pos, window, softcap_val, scale):
    """Grouped-query einsum without KV expansion — the decode path, where the
    KV cache is sequence-sharded and q is tiny (gathered)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.reshape(B, S, KV, rep, hd)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qh, k).astype(jnp.float32) * scale
    scores = softcap(scores, softcap_val)
    scores = scores + _mask_bias(q_pos, k_pos, window)[None, None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", p, v)
    return out.reshape(B, S, H, hd)


def _attend_direct(q, k, v, q_pos, k_pos, window, softcap_val, scale):
    """q,k,v: (B,S|T,H,hd) (KV pre-expanded). Direct O(S*T) scores."""
    B, S, H, hd = q.shape
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, softcap_val)
    scores = scores + _mask_bias(q_pos, k_pos, window)[None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", p, v)


def _attend_chunked(q, k, v, q_pos, k_pos, window, softcap_val, scale,
                    kv_block: int = 1024):
    """Online-softmax scan over KV blocks — O(S * kv_block) live memory.

    This is the XLA realization of the flash-attention schedule (the Pallas
    kernel in kernels/flash_attention.py is the TPU-tiled version); it makes
    32k-token prefill fit HBM without materializing (S, T) scores.
    q,k,v: (B, S|T, H, hd), KV pre-expanded to H.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    nblk = T // kv_block

    @jax.checkpoint
    def body(carry, blk):
        acc, m, l = carry
        kb, vb, kp = blk                       # (B,kvb,H,hd), (B,kvb,H,hd), (kvb,)
        s = jnp.einsum("bshd,bthd->bhst", q, kb).astype(jnp.float32) * scale
        s = softcap(s, softcap_val)
        s = s + _mask_bias(q_pos, kp, window)[None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhst,bthd->bhsd", p.astype(q.dtype), vb).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    kb = k.reshape(B, nblk, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nblk, kv_block)
    acc0 = jnp.zeros((B, H, S, hd), jnp.float32)
    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, kp))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.transpose(0, 2, 1, 3)  # (B, S, H, hd)


def attention(params: Params, x: Array, cfg: ModelConfig, *,
              layer_is_local: Array | bool = False,
              positions: Array | None = None,
              kv_cache: tuple[Array, Array] | None = None,
              cache_pos: Array | None = None):
    chunked_threshold = cfg.attn_direct_max
    """GQA attention. Training/prefill when kv_cache is None (returns y, (k, v));
    decode when kv_cache is given (x is (B, 1, D); returns y, updated cache)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = jnp.dtype(cfg.compute_dtype)
    wq, wk, wv, wo = (params[n].astype(cd) for n in ("wq", "wk", "wv", "wo"))
    if positions is None:
        positions = jnp.arange(S)

    q = (x @ wq).reshape(B, S, H, hd)
    k = (x @ wk).reshape(B, S, KV, hd)
    v = (x @ wv).reshape(B, S, KV, hd)
    if cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    window: Array | int = cfg.window if cfg.window > 0 else 0
    if cfg.local_global_pattern:
        # gemma2: even layers local (sliding window), odd layers global. Inside
        # scan-over-layers ``layer_is_local`` is a traced bool — the dynamic
        # window flows into the mask bias, so one attention serves both kinds.
        window = jnp.where(jnp.asarray(layer_is_local), cfg.window, 0)

    if kv_cache is not None:
        ck, cv = kv_cache                      # (B, T, KV, hd) preallocated
        T = ck.shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, 1)
        if S > chunked_threshold:
            # long multi-token prefill through the cache: online-softmax over
            # KV blocks, so the (S, T) score matrix never materializes (the
            # same escape the non-cache branch takes). Cache slots are padded
            # to a block multiple; pad positions sit beyond every query and
            # are causally masked.
            kvb = min(cfg.attn_kv_block, T)
            pad = (-T) % kvb
            rep = H // KV
            kf = _repeat_kv(ck.astype(cd), rep)
            vf = _repeat_kv(cv.astype(cd), rep)
            if pad:
                kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            out = _attend_chunked(q, kf, vf, positions, jnp.arange(T + pad),
                                  window, softcap_val=cfg.attn_softcap,
                                  scale=scale, kv_block=kvb)
        else:
            # grouped einsum: the cache stays sequence-sharded and un-expanded
            out = _attend_direct_g(q, ck.astype(cd), cv.astype(cd),
                                   positions, jnp.arange(T), window,
                                   cfg.attn_softcap, scale)
        y = out.reshape(B, S, H * hd) @ wo
        return y, (ck, cv)

    rep = H // KV
    kf, vf = _repeat_kv(k, rep), _repeat_kv(v, rep)
    if S % 16 == 0:
        # sequence-parallel attention (archs whose head count does not divide
        # the model axis, e.g. 40H/24H): shard S over `model` instead of
        # replicating the whole attention 16x — K/V are gathered per layer
        # (cheap) while scores/output compute 1/16th per device. No-op unless
        # the launcher installs the attn_seq rules.
        q = ctx.constrain(q, "attn_seq_q")
        kf = ctx.constrain(kf, "attn_seq_kv")
        vf = ctx.constrain(vf, "attn_seq_kv")
    kwargs = dict(softcap_val=cfg.attn_softcap, scale=scale)
    if S > chunked_threshold:
        kwargs["kv_block"] = min(cfg.attn_kv_block, S)
        out = _attend_chunked(q, kf, vf, positions, positions, window, **kwargs)
    else:
        out = _attend_direct(q, kf, vf, positions, positions, window, **kwargs)
    y = out.reshape(B, S, H * hd) @ wo
    return y, (k, v)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def init_mlp(key: Array, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    return {
        "w_gate": jax.random.normal(k1, (d, f), dt) * s_in,
        "w_up": jax.random.normal(k2, (d, f), dt) * s_in,
        "w_down": jax.random.normal(k3, (f, d), dt) * s_out,
    }


def mlp(params: Params, x: Array, cfg: ModelConfig) -> Array:
    cd = jnp.dtype(cfg.compute_dtype)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    g = act(x @ params["w_gate"].astype(cd))
    u = x @ params["w_up"].astype(cd)
    return (g * u) @ params["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity + scatter dispatch (EP over the model axis)
# ---------------------------------------------------------------------------

def init_moe(key: Array, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.expert_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    p = {
        "router": jax.random.normal(k1, (d, e), dt) * s_in,
        "w_gate": jax.random.normal(k2, (e, d, f), dt) * s_in,
        "w_up": jax.random.normal(k3, (e, d, f), dt) * s_in,
        "w_down": jax.random.normal(k4, (e, f, d), dt) * s_out,
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = init_mlp(k5, cfg, cfg.shared_expert_d_ff)
    return p


def _moe_dispatch_group(params: Params, xt: Array, cfg: ModelConfig, cap: int):
    """Route/dispatch for ONE token group. xt: (Tg, D)."""
    E, K = cfg.num_experts, cfg.top_k
    cd = jnp.dtype(cfg.compute_dtype)
    Tg, D = xt.shape

    logits = (xt @ params["router"].astype(cd)).astype(jnp.float32)   # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                               # (Tg, K)
    gate = (gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)).astype(cd)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (Tg * K)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # rank of each (token, k) within its expert, via cumsum over tokens
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)                  # (Tg,K,E)
    flat_oh = onehot.reshape(Tg * K, E)
    ranks = jnp.cumsum(flat_oh, axis=0) - flat_oh                      # exclusive
    rank = jnp.take_along_axis(ranks, eidx.reshape(Tg * K, 1), axis=1)[:, 0]
    keep = rank < cap
    dest = jnp.where(keep, eidx.reshape(-1) * cap + rank, E * cap)     # drop slot

    # index-only scatter (payload D elided): GSPMD replicates scatter
    # operands across shards, so scattering the (Tg*K, D) activations would
    # all-gather an (E*cap, D) buffer per layer (~170 GB/layer for qwen);
    # scattering 4-byte token ids then GATHERING activations stays local.
    src_tok = jnp.arange(Tg * K, dtype=jnp.int32) // K
    slot = jnp.full((E * cap + 1,), Tg, jnp.int32).at[dest].set(src_tok)
    xpad = jnp.concatenate([xt.astype(cd), jnp.zeros((1, D), cd)], axis=0)
    eb = xpad[slot[:-1]].reshape(E, cap, D)                            # gather
    return eb, dest, gate, aux


def moe(params: Params, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """Returns (y, aux_loss). Tokens are routed top-k and scatter-dispatched
    into per-expert capacity buffers PER GROUP (``moe_groups`` = the data
    shards): routing, rank-cumsum and the scatter are all group-local, so the
    only cross-device movement is the expert einsum's own sharding (EP
    all-to-all when experts are model-sharded; nothing when experts are
    replicated with model-sharded hidden). A single global scatter instead
    makes GSPMD replicate + all-reduce the whole (E*cap, D) dispatch buffer
    per layer. Over-capacity tokens are dropped (the residual carries them)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    cd = jnp.dtype(cfg.compute_dtype)
    T = B * S
    G = cfg.moe_groups if T % cfg.moe_groups == 0 and T >= cfg.moe_groups else 1
    cap = max(1, int(cfg.capacity_factor * (T // G) * K / E))
    xt = x.reshape(G, T // G, D)

    eb, dest, gate, aux = jax.vmap(
        lambda xg: _moe_dispatch_group(params, xg, cfg, cap))(xt)
    aux = aux.mean()
    # pin the dispatch buffer to the group (data) axis — the batched gather's
    # partitioning is otherwise undecided and GSPMD replicates it (40 GiB/op)
    eb = ctx.constrain(eb, "moe_eb")

    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    g = act(jnp.einsum("gecd,edf->gecf", eb, params["w_gate"].astype(cd)))
    g = ctx.constrain(g, "moe_hidden")
    u = ctx.constrain(jnp.einsum("gecd,edf->gecf", eb,
                                 params["w_up"].astype(cd)), "moe_hidden")
    out = jnp.einsum("gecf,efd->gecd", g * u, params["w_down"].astype(cd))
    out = ctx.constrain(out, "moe_eb")

    def combine_group(out_g, dest_g, gate_g):
        flat = jnp.concatenate(
            [out_g.reshape(E * cap, D), jnp.zeros((1, D), cd)], axis=0)
        gathered = flat[dest_g].reshape(T // G, K, D)                  # dropped->0
        return jnp.einsum("tkd,tk->td", gathered, gate_g)

    y = jax.vmap(combine_group)(out, dest, gate).reshape(B, S, D)

    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg).reshape(B, S, D)
    return y, aux
