from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    param_count,
    prefill,
)
