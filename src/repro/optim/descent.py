"""popt4jlib.GradientDescent — classical saddle-point methods, JAX-native.

  ASD   steepest descent + Armijo rule with restarts
        (Fig.4 params: rho=0.1, beta=0.8, gamma=1, gtol=1e-6)
  FCG   conjugate gradient, Fletcher-Reeves or Polak-Ribiere updates, restarts
        (the paper's Fletcher bracketing/sectioning line search with params
        rho, sigma, t1, t2, t3 is realized here as Armijo backtracking — same
        sufficient-decrease acceptance, simpler bracketing; deviation recorded
        in DESIGN.md §6)
  AVD   alternating-variables descent with expanding coordinate probes and
        optional quantization of variables (box + discrete sets)
  BFGS  Newton's method with dense BFGS updates + Armijo steps

All methods are budget-capped in *function evaluations* (Fig.4 protocol) and use
Richardson numeric gradients by default (4D evals per gradient, charged to the
budget exactly as the paper does). Whole runs are single jitted
``lax.while_loop``s — one XLA program per (method, function, dim).

The module has two faces (popt4jlib ``LocalOptimizerIntf``):

* standalone optimizers (``asd``/``fcg``/``avd``/``bfgs`` above) — multistart,
  budget-driven ``while_loop`` runs for Fig.4-style experiments;
* the **batched polish layer** (``PolishConfig`` / ``make_polish``) — a
  fixed-iteration, fixed-shape, deterministic variant of the same four methods
  that refines a ``(K, dim)`` batch of candidates in one shot. It is jit-,
  vmap- and scan-safe (no data-dependent shapes, no host syncs, no RNG), routes
  every probe and line-search trial through a pluggable batch evaluator (the
  engine's xla/pallas EvalBackend), and has a statically known eval cost
  (``polish_evals_per_point``) so the island engine can charge polish work to
  the run budget exactly. This is the hybrid memetic layer of DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import OptimizeResult
from repro.functions.benchmarks import Function
from repro.optim.numgrad import make_grad

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DescentConfig:
    """Standalone descent-run parameters: eval budget, Armijo line search,
    gradient cost model and the AVD quantization/probe controls."""

    max_evals: int = 100_000
    rho: float = 0.1          # Armijo sufficient-decrease
    beta: float = 0.8         # Armijo backtracking factor
    gamma: float = 1.0        # Armijo initial step
    gtol: float = 1e-6
    max_backtracks: int = 40
    grad_mode: str = "richardson"   # richardson | autodiff
    cg_update: str = "fr"     # fr | pr
    avd_quantum: float = 0.0  # >0: variables restricted to multiples of quantum
    avd_expansions: int = 8


def _armijo(fn, x, fx, g, d, cfg: DescentConfig):
    """Backtracking Armijo along d. Returns (x_new, f_new, evals_used).

    The direction is normalized so the initial trial step ``gamma`` is a
    *distance* in the box — without this, 1000-D Rosenbrock-scale gradients
    (|g| ~ 1e7) overshoot any backtracking budget and every iteration stalls
    into a restart."""
    d = d / jnp.maximum(jnp.linalg.norm(d), 1e-30)
    gd = jnp.sum(g * d)

    def cond(c):
        t, f_t, k = c
        return (f_t > fx + cfg.rho * t * gd) & (k < cfg.max_backtracks)

    def body(c):
        t, _, k = c
        t2 = t * cfg.beta
        return t2, fn(x + t2 * d), k + 1

    t0 = jnp.asarray(cfg.gamma, x.dtype)
    t, f_t, k = jax.lax.while_loop(cond, body, (t0, fn(x + t0 * d), jnp.asarray(0)))
    ok = f_t <= fx + cfg.rho * t * gd
    return (jnp.where(ok, x + t * d, x), jnp.where(ok, f_t, fx), k + 1)


class _Carry(NamedTuple):
    x: Array
    fx: Array
    g: Array
    d: Array
    gg_prev: Array
    evals: Array
    best_x: Array
    best_f: Array
    key: Array


def _directional(f: Function, key: Array, dim: int, cfg: DescentConfig,
                 method: str) -> OptimizeResult:
    """Shared restarted-descent driver for ASD and FCG."""
    lo, hi = f.lo, f.hi
    grad_fn = make_grad(f.fn, cfg.grad_mode)

    def rand_point(k):
        return jax.random.uniform(k, (dim,), minval=lo, maxval=hi)

    def run(key):
        kx, kr = jax.random.split(key)
        x0 = rand_point(kx)
        fx0 = f.fn(x0)
        g0, ge = grad_fn(x0)
        c0 = _Carry(x0, fx0, g0, -g0, jnp.sum(g0 * g0),
                    jnp.asarray(ge + 1), x0, fx0, kr)

        def cond(c: _Carry):
            return c.evals < cfg.max_evals

        def body(c: _Carry):
            x1, f1, ls_evals = _armijo(f.fn, c.x, c.fx, c.g, c.d, cfg)
            g1, ge = grad_fn(x1)
            gg1 = jnp.sum(g1 * g1)
            if method == "fcg":
                if cfg.cg_update == "fr":
                    b = gg1 / jnp.maximum(c.gg_prev, 1e-30)
                else:  # PR+
                    b = jnp.maximum(
                        jnp.sum(g1 * (g1 - c.g)) / jnp.maximum(c.gg_prev, 1e-30), 0.0)
                d1 = -g1 + b * c.d
                d1 = jnp.where(jnp.sum(d1 * g1) < 0, d1, -g1)  # keep descent
            else:
                d1 = -g1
            # multistart: restart from a random point when converged/stalled
            done = (jnp.sqrt(gg1) < cfg.gtol) | (f1 >= c.fx - 1e-15)
            key, rk = jax.random.split(c.key)
            xr = rand_point(rk)
            fr = f.fn(xr)
            gr, ger = grad_fn(xr)
            x2 = jnp.where(done, xr, x1)
            f2 = jnp.where(done, fr, f1)
            g2 = jnp.where(done, gr, g1)
            d2 = jnp.where(done, -gr, d1)
            gg2 = jnp.where(done, jnp.sum(gr * gr), gg1)
            evals = c.evals + ls_evals + ge + jnp.where(done, ger + 1, 0)
            best = f2 < c.best_f
            return _Carry(x2, f2, g2, d2, gg2, evals,
                          jnp.where(best, x2, c.best_x),
                          jnp.where(best, f2, c.best_f), key)

        return jax.lax.while_loop(cond, body, c0)

    out = jax.jit(run)(key)
    return OptimizeResult(arg=out.best_x, value=float(out.best_f),
                          n_evals=int(out.evals))


def asd(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    """ArmijoSteepestDescent: multistart steepest descent, budget-capped."""
    return _directional(f, key, dim, cfg, "asd")


def fcg(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    """FletcherConjugateGradient: multistart nonlinear CG (FR or PR+)."""
    return _directional(f, key, dim, cfg, "fcg")


# ---------------------------------------------------------------------------
# Batched polish layer — popt4jlib LocalOptimizerIntf inside the island engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolishConfig:
    """Fixed-shape local-descent polish of a candidate batch (DESIGN.md §6).

    Unlike :class:`DescentConfig` runs, a polish is *iteration*-capped, not
    budget-capped: ``steps`` descent iterations, each costing a statically
    known number of evaluations (see :func:`polish_evals_per_point`), so the
    engine can account polish work against its eval budget before tracing.
    The backtracking ``while_loop`` of ``_armijo`` becomes a *ladder*: all
    ``n_ladder`` trial steps are evaluated as one batch through the evaluator
    (one fused backend call instead of a sequential loop), and the largest
    Armijo-admissible step wins — falling back to the best improving trial,
    or to the incumbent itself, so polish is monotone by construction.
    """

    method: str = "asd"       # asd | fcg | avd | bfgs
    steps: int = 3            # descent iterations per polish call
    n_ladder: int = 8         # line-search trial steps, gamma * beta^j
    gamma: float = 1.0        # largest trial step (a distance: directions are
                              # normalized, exactly like ``_armijo``)
    beta: float = 0.5         # ladder decay
    rho: float = 1e-4         # Armijo sufficient-decrease slope
    grad_h: float = 1e-4      # Richardson probe step
    avd_span: float = 0.1     # AVD: largest probe, as a fraction of (hi - lo)

    def __post_init__(self) -> None:
        if self.method not in ("asd", "fcg", "avd", "bfgs"):
            raise ValueError(f"unknown polish method {self.method!r}")


def polish_evals_per_point(dim: int, cfg: PolishConfig) -> int:
    """Function evaluations one polished point costs — exact, by construction.

    Gradient methods: per step, one Richardson gradient (4·dim probes) plus
    ``n_ladder`` line-search trials. AVD: per step, a ±ladder probe on every
    coordinate (2·dim·n_ladder), from which the single best move is taken.
    """
    if cfg.method == "avd":
        return cfg.steps * 2 * dim * cfg.n_ladder
    return cfg.steps * (4 * dim + cfg.n_ladder)


def _batched_richardson(evaluate, x: Array, h: float) -> Array:
    """Richardson 4th-order gradients for a (K, D) batch, all 4·K·D probe
    points in ONE evaluator call — the polish analogue of ``richardson_grad``
    that hits the engine's xla/pallas backend instead of a raw vmap."""
    K, D = x.shape
    eye = jnp.eye(D, dtype=x.dtype)
    probes = jnp.concatenate([
        x[:, None, :] + h * eye, x[:, None, :] - h * eye,
        x[:, None, :] + 2 * h * eye, x[:, None, :] - 2 * h * eye,
    ], axis=1)                                            # (K, 4D, D)
    vals = evaluate(probes.reshape(K * 4 * D, D)).reshape(K, 4, D)
    fp, fm, fp2, fm2 = vals[:, 0], vals[:, 1], vals[:, 2], vals[:, 3]
    return (8.0 * (fp - fm) - (fp2 - fm2)) / (12.0 * h)


def _ladder_search(evaluate, x: Array, fx: Array, g: Array, d: Array,
                   lo: float, hi: float, cfg: PolishConfig) -> tuple[Array, Array]:
    """Batched Armijo ladder along per-row directions ``d``.

    Evaluates the whole geometric ladder ``gamma·beta^j`` at once, accepts the
    largest admissible step per row (or the best improving trial when none
    passes Armijo — box clipping can break the slope condition near a bound),
    and never moves a row uphill."""
    K, D = x.shape
    L = cfg.n_ladder
    dn = d / jnp.maximum(jnp.linalg.norm(d, axis=-1, keepdims=True), 1e-30)
    gd = jnp.sum(g * dn, axis=-1)                          # (K,)
    ts = cfg.gamma * cfg.beta ** jnp.arange(L, dtype=x.dtype)
    cand = jnp.clip(x[:, None, :] + ts[None, :, None] * dn[:, None, :], lo, hi)
    fc = evaluate(cand.reshape(K * L, D)).reshape(K, L)
    ok = fc <= fx[:, None] + cfg.rho * ts[None, :] * gd[:, None]
    j = jnp.where(jnp.any(ok, axis=1), jnp.argmax(ok, axis=1),
                  jnp.argmin(fc, axis=1))
    xj = jnp.take_along_axis(cand, j[:, None, None], axis=1)[:, 0]
    fj = jnp.take_along_axis(fc, j[:, None], axis=1)[:, 0]
    better = fj < fx
    return jnp.where(better[:, None], xj, x), jnp.where(better, fj, fx)


def make_polish(f: Function, evaluate, dim: int,
                cfg: PolishConfig = PolishConfig()):
    """Build ``polish(xs (K, dim), fs (K,)) -> (xs', fs')`` for objective ``f``.

    The returned callable is pure, deterministic and fixed-shape: safe inside
    ``jit``/``vmap``/``scan`` (the island engine calls it from inside its
    jitted round scan, vmapped over islands and again over jobs). ``evaluate``
    is a ``(N, dim) -> (N,)`` batch evaluator — pass the engine's
    ``make_batch_evaluator`` product so polish probes hit the same xla/pallas
    backend as generation steps, or ``None`` for a plain vmap of ``f.fn``.

    ASD/FCG(FR)/BFGS carry direction/curvature memory across the ``steps``
    iterations of one call and restart fresh each call; AVD is realized as a
    greedy best-single-coordinate-move per step (the batched analogue of one
    sweep — the sequential coordinate loop of :func:`avd` does not vectorize
    over a candidate batch; deviation noted in DESIGN.md §6).
    """
    if evaluate is None:
        evaluate = jax.vmap(f.fn)
    lo, hi = f.lo, f.hi
    L = cfg.n_ladder

    if cfg.method == "avd":
        span = cfg.avd_span * (hi - lo)

        def polish_avd(xs: Array, fs: Array) -> tuple[Array, Array]:
            K, D = xs.shape
            ts = span * cfg.beta ** jnp.arange(L, dtype=xs.dtype)   # (L,)
            eye = jnp.eye(D, dtype=xs.dtype)
            # (K, D, 2, L, D): per point, per coordinate, ± each ladder step
            moves = eye[None, :, None, None, :] * ts[None, None, None, :, None]
            moves = moves * jnp.asarray([1.0, -1.0], xs.dtype)[None, None, :, None, None]

            def step(carry: tuple[Array, Array], _: None):
                x, fx = carry
                cand = jnp.clip(x[:, None, None, None, :] + moves, lo, hi)
                fc = evaluate(cand.reshape(K * D * 2 * L, D)).reshape(K, D * 2 * L)
                j = jnp.argmin(fc, axis=1)
                fj = jnp.take_along_axis(fc, j[:, None], axis=1)[:, 0]
                xj = jnp.take_along_axis(
                    cand.reshape(K, D * 2 * L, D), j[:, None, None], axis=1)[:, 0]
                better = fj < fx
                return (jnp.where(better[:, None], xj, x),
                        jnp.where(better, fj, fx)), None

            (xs, fs), _ = jax.lax.scan(step, (xs, fs), None, length=cfg.steps)
            return xs, fs

        return polish_avd

    method = cfg.method

    def polish_grad(xs: Array, fs: Array) -> tuple[Array, Array]:
        # The scan carry holds only what the method reads — at dim=1000 a
        # dense (K, D, D) BFGS matrix is 4*K MB, so asd/fcg must not drag it
        # through the engine's round scan (and its islands/jobs vmaps).
        K, D = xs.shape

        def step(carry, _: None):
            if method == "fcg":
                x, fx, d_prev, gg_prev = carry
            elif method == "bfgs":
                x, fx, x_prev, g_prev, H = carry
            else:                          # asd
                x, fx = carry
            g = _batched_richardson(evaluate, x, cfg.grad_h)
            if method == "fcg":
                gg = jnp.sum(g * g, axis=-1)
                b = gg / gg_prev           # first step: gg_prev = inf -> b = 0
                d = -g + b[:, None] * d_prev
                dg = jnp.sum(d * g, axis=-1)
                d = jnp.where((dg < 0)[:, None], d, -g)    # keep descent
            elif method == "bfgs":
                I = jnp.broadcast_to(jnp.eye(D, dtype=x.dtype), (K, D, D))
                s, y = x - x_prev, g - g_prev
                sy = jnp.sum(s * y, axis=-1)
                ok = sy > 1e-10            # first step: s = 0 -> H stays I
                r = jnp.where(ok, 1.0 / jnp.where(ok, sy, 1.0), 0.0)
                V = I - r[:, None, None] * s[:, :, None] * y[:, None, :]
                H1 = (V @ H @ jnp.swapaxes(V, 1, 2)
                      + r[:, None, None] * s[:, :, None] * s[:, None, :])
                H = jnp.where(ok[:, None, None], H1, H)
                d = -jnp.einsum("kij,kj->ki", H, g)
                dg = jnp.sum(d * g, axis=-1)
                d = jnp.where((dg < 0)[:, None], d, -g)
            else:                          # asd
                d = -g
            x1, f1 = _ladder_search(evaluate, x, fx, g, d, lo, hi, cfg)
            if method == "fcg":
                return (x1, f1, d, gg), None
            if method == "bfgs":
                return (x1, f1, x, g, H), None
            return (x1, f1), None

        if method == "fcg":
            carry0 = (xs, fs, jnp.zeros_like(xs),
                      jnp.full((K,), jnp.inf, xs.dtype))
        elif method == "bfgs":
            carry0 = (xs, fs, xs, jnp.zeros_like(xs),
                      jnp.broadcast_to(jnp.eye(D, dtype=xs.dtype), (K, D, D)))
        else:
            carry0 = (xs, fs)
        (xs, fs, *_), _ = jax.lax.scan(step, carry0, None, length=cfg.steps)
        return xs, fs

    return polish_grad


# ---------------------------------------------------------------------------
# AVD — AlternatingVariablesDescent
# ---------------------------------------------------------------------------

def avd(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    """One variable at a time with doubling probe steps both ways; a stalled
    sweep triggers a random restart. ``avd_quantum`` > 0 restricts moves to
    integer multiples of the quantum (the paper's discrete-variable support)."""
    lo, hi = f.lo, f.hi
    q = cfg.avd_quantum
    step0 = 0.1 * (hi - lo) if q <= 0 else q

    def snap(v):
        return v if q <= 0 else jnp.round(v / q) * q

    def coord_step(i, carry):
        x, fx, evals = carry
        e = jax.nn.one_hot(i, dim, dtype=x.dtype)

        def direction(sgn, bx, bf, ev):
            # geometric ladder both coarser and finer than step0, so each
            # coordinate can both escape (×2^E) and refine (×2^-E)
            for j in range(-cfg.avd_expansions, cfg.avd_expansions + 1):
                st = snap(jnp.asarray(step0 * (2.0 ** j), x.dtype))
                cand = jnp.clip(bx + sgn * st * e, lo, hi)
                fc = f.fn(cand)
                better = fc < bf
                bx = jnp.where(better, cand, bx)
                bf = jnp.where(better, fc, bf)
                ev = ev + 1
            return bx, bf, ev

        x1, f1, evals = direction(1.0, x, fx, evals)
        x1, f1, evals = direction(-1.0, x1, f1, evals)
        return x1, f1, evals

    def run(key):
        kx, kr = jax.random.split(key)
        x = snap(jax.random.uniform(kx, (dim,), minval=lo, maxval=hi))
        fx = f.fn(x)

        def cond(c):
            return c[2] < cfg.max_evals

        def body(c):
            x, fx, evals, bx, bf, key = c
            x1, f1, evals = jax.lax.fori_loop(0, dim, coord_step, (x, fx, evals))
            stalled = f1 >= fx - 1e-15
            key, rk = jax.random.split(key)
            xr = snap(jax.random.uniform(rk, (dim,), minval=lo, maxval=hi))
            fr = f.fn(xr)
            x2 = jnp.where(stalled, xr, x1)
            f2 = jnp.where(stalled, fr, f1)
            evals = evals + jnp.where(stalled, 1, 0)
            best = f2 < bf
            return (x2, f2, evals,
                    jnp.where(best, x2, bx), jnp.where(best, f2, bf), key)

        out = jax.lax.while_loop(cond, body, (x, fx, jnp.asarray(1), x, fx, kr))
        return out[3], out[4], out[2]

    bx, bf, ev = jax.jit(run)(key)
    return OptimizeResult(arg=bx, value=float(bf), n_evals=int(ev))


# ---------------------------------------------------------------------------
# BFGS — Newton's method with BFGS updates + Armijo
# ---------------------------------------------------------------------------

def bfgs(f: Function, key: Array, dim: int,
         cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    """Quasi-Newton descent with dense BFGS updates + Armijo steps."""
    lo, hi = f.lo, f.hi
    grad_fn = make_grad(f.fn, cfg.grad_mode)

    def run(key):
        kx, kr = jax.random.split(key)
        x = jax.random.uniform(kx, (dim,), minval=lo, maxval=hi)
        fx = f.fn(x)
        g, ge = grad_fn(x)
        I = jnp.eye(dim, dtype=x.dtype)

        def cond(c):
            return c[-1] < cfg.max_evals

        def body(c):
            x, fx, g, H, bx, bf, key, evals = c
            d = -(H @ g)
            d = jnp.where(jnp.sum(d * g) < 0, d, -g)
            x1, f1, ls = _armijo(f.fn, x, fx, g, d, cfg)
            g1, ge = grad_fn(x1)
            s, y = x1 - x, g1 - g
            sy = jnp.sum(s * y)
            ok = sy > 1e-10
            rho_ = jnp.where(ok, 1.0 / jnp.where(ok, sy, 1.0), 0.0)
            V = I - rho_ * jnp.outer(s, y)
            H1 = jnp.where(ok, V @ H @ V.T + rho_ * jnp.outer(s, s), H)
            done = jnp.linalg.norm(g1) < cfg.gtol
            key, rk = jax.random.split(key)
            xr = jax.random.uniform(rk, x.shape, minval=lo, maxval=hi)
            fr = f.fn(xr)
            gr, ger = grad_fn(xr)
            x2 = jnp.where(done, xr, x1)
            f2 = jnp.where(done, fr, f1)
            g2 = jnp.where(done, gr, g1)
            H2 = jnp.where(done, I, H1)
            evals = evals + ls + ge + jnp.where(done, ger + 1, 0)
            best = f2 < bf
            return (x2, f2, g2, H2, jnp.where(best, x2, bx),
                    jnp.where(best, f2, bf), key, evals)

        out = jax.lax.while_loop(
            cond, body, (x, fx, g, I, x, fx, kr, jnp.asarray(ge + 1)))
        return out[4], out[5], out[7]

    bx, bf, ev = jax.jit(run)(key)
    return OptimizeResult(arg=bx, value=float(bf), n_evals=int(ev))
