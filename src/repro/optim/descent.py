"""popt4jlib.GradientDescent — classical saddle-point methods, JAX-native.

  ASD   steepest descent + Armijo rule with restarts
        (Fig.4 params: rho=0.1, beta=0.8, gamma=1, gtol=1e-6)
  FCG   conjugate gradient, Fletcher-Reeves or Polak-Ribiere updates, restarts
        (the paper's Fletcher bracketing/sectioning line search with params
        rho, sigma, t1, t2, t3 is realized here as Armijo backtracking — same
        sufficient-decrease acceptance, simpler bracketing; deviation recorded
        in DESIGN.md §9)
  AVD   alternating-variables descent with expanding coordinate probes and
        optional quantization of variables (box + discrete sets)
  BFGS  Newton's method with dense BFGS updates + Armijo steps

All methods are budget-capped in *function evaluations* (Fig.4 protocol) and use
Richardson numeric gradients by default (4D evals per gradient, charged to the
budget exactly as the paper does). Whole runs are single jitted
``lax.while_loop``s — one XLA program per (method, function, dim).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import OptimizeResult
from repro.functions.benchmarks import Function
from repro.optim.numgrad import make_grad

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DescentConfig:
    max_evals: int = 100_000
    rho: float = 0.1          # Armijo sufficient-decrease
    beta: float = 0.8         # Armijo backtracking factor
    gamma: float = 1.0        # Armijo initial step
    gtol: float = 1e-6
    max_backtracks: int = 40
    grad_mode: str = "richardson"   # richardson | autodiff
    cg_update: str = "fr"     # fr | pr
    avd_quantum: float = 0.0  # >0: variables restricted to multiples of quantum
    avd_expansions: int = 8


def _armijo(fn, x, fx, g, d, cfg: DescentConfig):
    """Backtracking Armijo along d. Returns (x_new, f_new, evals_used).

    The direction is normalized so the initial trial step ``gamma`` is a
    *distance* in the box — without this, 1000-D Rosenbrock-scale gradients
    (|g| ~ 1e7) overshoot any backtracking budget and every iteration stalls
    into a restart."""
    d = d / jnp.maximum(jnp.linalg.norm(d), 1e-30)
    gd = jnp.sum(g * d)

    def cond(c):
        t, f_t, k = c
        return (f_t > fx + cfg.rho * t * gd) & (k < cfg.max_backtracks)

    def body(c):
        t, _, k = c
        t2 = t * cfg.beta
        return t2, fn(x + t2 * d), k + 1

    t0 = jnp.asarray(cfg.gamma, x.dtype)
    t, f_t, k = jax.lax.while_loop(cond, body, (t0, fn(x + t0 * d), jnp.asarray(0)))
    ok = f_t <= fx + cfg.rho * t * gd
    return (jnp.where(ok, x + t * d, x), jnp.where(ok, f_t, fx), k + 1)


class _Carry(NamedTuple):
    x: Array
    fx: Array
    g: Array
    d: Array
    gg_prev: Array
    evals: Array
    best_x: Array
    best_f: Array
    key: Array


def _directional(f: Function, key: Array, dim: int, cfg: DescentConfig,
                 method: str) -> OptimizeResult:
    """Shared restarted-descent driver for ASD and FCG."""
    lo, hi = f.lo, f.hi
    grad_fn = make_grad(f.fn, cfg.grad_mode)

    def rand_point(k):
        return jax.random.uniform(k, (dim,), minval=lo, maxval=hi)

    def run(key):
        kx, kr = jax.random.split(key)
        x0 = rand_point(kx)
        fx0 = f.fn(x0)
        g0, ge = grad_fn(x0)
        c0 = _Carry(x0, fx0, g0, -g0, jnp.sum(g0 * g0),
                    jnp.asarray(ge + 1), x0, fx0, kr)

        def cond(c: _Carry):
            return c.evals < cfg.max_evals

        def body(c: _Carry):
            x1, f1, ls_evals = _armijo(f.fn, c.x, c.fx, c.g, c.d, cfg)
            g1, ge = grad_fn(x1)
            gg1 = jnp.sum(g1 * g1)
            if method == "fcg":
                if cfg.cg_update == "fr":
                    b = gg1 / jnp.maximum(c.gg_prev, 1e-30)
                else:  # PR+
                    b = jnp.maximum(
                        jnp.sum(g1 * (g1 - c.g)) / jnp.maximum(c.gg_prev, 1e-30), 0.0)
                d1 = -g1 + b * c.d
                d1 = jnp.where(jnp.sum(d1 * g1) < 0, d1, -g1)  # keep descent
            else:
                d1 = -g1
            # multistart: restart from a random point when converged/stalled
            done = (jnp.sqrt(gg1) < cfg.gtol) | (f1 >= c.fx - 1e-15)
            key, rk = jax.random.split(c.key)
            xr = rand_point(rk)
            fr = f.fn(xr)
            gr, ger = grad_fn(xr)
            x2 = jnp.where(done, xr, x1)
            f2 = jnp.where(done, fr, f1)
            g2 = jnp.where(done, gr, g1)
            d2 = jnp.where(done, -gr, d1)
            gg2 = jnp.where(done, jnp.sum(gr * gr), gg1)
            evals = c.evals + ls_evals + ge + jnp.where(done, ger + 1, 0)
            best = f2 < c.best_f
            return _Carry(x2, f2, g2, d2, gg2, evals,
                          jnp.where(best, x2, c.best_x),
                          jnp.where(best, f2, c.best_f), key)

        return jax.lax.while_loop(cond, body, c0)

    out = jax.jit(run)(key)
    return OptimizeResult(arg=out.best_x, value=float(out.best_f),
                          n_evals=int(out.evals))


def asd(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    return _directional(f, key, dim, cfg, "asd")


def fcg(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    return _directional(f, key, dim, cfg, "fcg")


# ---------------------------------------------------------------------------
# AVD — AlternatingVariablesDescent
# ---------------------------------------------------------------------------

def avd(f: Function, key: Array, dim: int,
        cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    """One variable at a time with doubling probe steps both ways; a stalled
    sweep triggers a random restart. ``avd_quantum`` > 0 restricts moves to
    integer multiples of the quantum (the paper's discrete-variable support)."""
    lo, hi = f.lo, f.hi
    q = cfg.avd_quantum
    step0 = 0.1 * (hi - lo) if q <= 0 else q

    def snap(v):
        return v if q <= 0 else jnp.round(v / q) * q

    def coord_step(i, carry):
        x, fx, evals = carry
        e = jax.nn.one_hot(i, dim, dtype=x.dtype)

        def direction(sgn, bx, bf, ev):
            # geometric ladder both coarser and finer than step0, so each
            # coordinate can both escape (×2^E) and refine (×2^-E)
            for j in range(-cfg.avd_expansions, cfg.avd_expansions + 1):
                st = snap(jnp.asarray(step0 * (2.0 ** j), x.dtype))
                cand = jnp.clip(bx + sgn * st * e, lo, hi)
                fc = f.fn(cand)
                better = fc < bf
                bx = jnp.where(better, cand, bx)
                bf = jnp.where(better, fc, bf)
                ev = ev + 1
            return bx, bf, ev

        x1, f1, evals = direction(1.0, x, fx, evals)
        x1, f1, evals = direction(-1.0, x1, f1, evals)
        return x1, f1, evals

    def run(key):
        kx, kr = jax.random.split(key)
        x = snap(jax.random.uniform(kx, (dim,), minval=lo, maxval=hi))
        fx = f.fn(x)

        def cond(c):
            return c[2] < cfg.max_evals

        def body(c):
            x, fx, evals, bx, bf, key = c
            x1, f1, evals = jax.lax.fori_loop(0, dim, coord_step, (x, fx, evals))
            stalled = f1 >= fx - 1e-15
            key, rk = jax.random.split(key)
            xr = snap(jax.random.uniform(rk, (dim,), minval=lo, maxval=hi))
            fr = f.fn(xr)
            x2 = jnp.where(stalled, xr, x1)
            f2 = jnp.where(stalled, fr, f1)
            evals = evals + jnp.where(stalled, 1, 0)
            best = f2 < bf
            return (x2, f2, evals,
                    jnp.where(best, x2, bx), jnp.where(best, f2, bf), key)

        out = jax.lax.while_loop(cond, body, (x, fx, jnp.asarray(1), x, fx, kr))
        return out[3], out[4], out[2]

    bx, bf, ev = jax.jit(run)(key)
    return OptimizeResult(arg=bx, value=float(bf), n_evals=int(ev))


# ---------------------------------------------------------------------------
# BFGS — Newton's method with BFGS updates + Armijo
# ---------------------------------------------------------------------------

def bfgs(f: Function, key: Array, dim: int,
         cfg: DescentConfig = DescentConfig()) -> OptimizeResult:
    lo, hi = f.lo, f.hi
    grad_fn = make_grad(f.fn, cfg.grad_mode)

    def run(key):
        kx, kr = jax.random.split(key)
        x = jax.random.uniform(kx, (dim,), minval=lo, maxval=hi)
        fx = f.fn(x)
        g, ge = grad_fn(x)
        I = jnp.eye(dim, dtype=x.dtype)

        def cond(c):
            return c[-1] < cfg.max_evals

        def body(c):
            x, fx, g, H, bx, bf, key, evals = c
            d = -(H @ g)
            d = jnp.where(jnp.sum(d * g) < 0, d, -g)
            x1, f1, ls = _armijo(f.fn, x, fx, g, d, cfg)
            g1, ge = grad_fn(x1)
            s, y = x1 - x, g1 - g
            sy = jnp.sum(s * y)
            ok = sy > 1e-10
            rho_ = jnp.where(ok, 1.0 / jnp.where(ok, sy, 1.0), 0.0)
            V = I - rho_ * jnp.outer(s, y)
            H1 = jnp.where(ok, V @ H @ V.T + rho_ * jnp.outer(s, s), H)
            done = jnp.linalg.norm(g1) < cfg.gtol
            key, rk = jax.random.split(key)
            xr = jax.random.uniform(rk, x.shape, minval=lo, maxval=hi)
            fr = f.fn(xr)
            gr, ger = grad_fn(xr)
            x2 = jnp.where(done, xr, x1)
            f2 = jnp.where(done, fr, f1)
            g2 = jnp.where(done, gr, g1)
            H2 = jnp.where(done, I, H1)
            evals = evals + ls + ge + jnp.where(done, ger + 1, 0)
            best = f2 < bf
            return (x2, f2, g2, H2, jnp.where(best, x2, bx),
                    jnp.where(best, f2, bf), key, evals)

        out = jax.lax.while_loop(
            cond, body, (x, fx, g, I, x, fx, kr, jnp.asarray(ge + 1)))
        return out[4], out[5], out[7]

    bx, bf, ev = jax.jit(run)(key)
    return OptimizeResult(arg=bx, value=float(bf), n_evals=int(ev))
