from repro.optim import adam  # noqa: F401
from repro.optim.adam import AdamConfig, AdamState  # noqa: F401
from repro.optim.descent import DescentConfig, asd, avd, bfgs, fcg  # noqa: F401
from repro.optim.numgrad import make_grad, richardson_grad  # noqa: F401
