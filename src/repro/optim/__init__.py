from repro.optim import adam  # noqa: F401
from repro.optim.adam import AdamConfig, AdamState  # noqa: F401
from repro.optim.descent import (  # noqa: F401
    DescentConfig, PolishConfig, asd, avd, bfgs, fcg, make_polish,
    polish_evals_per_point)
from repro.optim.numgrad import make_grad, richardson_grad  # noqa: F401
