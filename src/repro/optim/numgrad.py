"""Parallel gradient approximation — popt4jlib's ``analysis`` package.

The paper: "Methods requiring derivative information use Richardson's 4th order
extrapolation, and every function evaluation needed for the estimation of the
derivative counts towards the limit on function evaluations."

Richardson 4th-order central difference:
    f'(x) ~ [8 (f(x+h) - f(x-h)) - (f(x+2h) - f(x-2h))] / (12 h)
i.e. 4 evaluations per dimension. The Java library evaluates gradient components
in parallel threads; here the 4*D probe points are a single vmapped batch (and
shard over the mesh under the engine's executor when present).

``grad_mode="autodiff"`` is the beyond-paper option (free on TPU; charged as 2
evaluation-equivalents, the standard reverse-mode cost model).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def richardson_grad(f: Callable[[Array], Array], x: Array, h: float = 1e-4):
    """Return (grad, n_evals). 4*D function evaluations, fully vectorized."""
    d = x.shape[-1]
    eye = jnp.eye(d, dtype=x.dtype)
    probes = jnp.concatenate([
        x + h * eye, x - h * eye, x + 2 * h * eye, x - 2 * h * eye,
    ], axis=0)                                     # (4D, D)
    vals = jax.vmap(f)(probes)                     # (4D,)
    fp, fm, fp2, fm2 = jnp.split(vals, 4)
    g = (8.0 * (fp - fm) - (fp2 - fm2)) / (12.0 * h)
    return g, 4 * d


def make_grad(f: Callable[[Array], Array], mode: str = "richardson", h: float = 1e-4):
    """Return ``grad_fn(x) -> (g, n_evals)`` under the chosen cost model."""
    if mode == "richardson":
        return lambda x: richardson_grad(f, x, h)
    if mode == "autodiff":
        gf = jax.grad(f)
        return lambda x: (gf(x), 2)
    raise ValueError(f"unknown grad mode {mode!r}")
