"""Adam — popt4jlib.GradientDescent.stochastic.Adam [9], in two forms.

1. ``adam_minimize``: the paper's FunctionIntf optimizer (budget-capped,
   Richardson or autodiff gradients) for the Fig.4-style testbed.
2. ``init``/``update``: a pytree Adam(W) for the LM training substrate — this is
   the paper's Adam running as the production trainer, with decoupled weight
   decay, global-norm clipping and a warmup+cosine schedule. Pure functions:
   the distribution layer shards the state like the params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import OptimizeResult
from repro.functions.benchmarks import Function
from repro.optim.numgrad import make_grad

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    """Pytree-Adam hyperparameters: moments, decoupled weight decay,
    global-norm clip and the warmup+cosine learning-rate schedule."""

    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0          # global-norm clip; <=0 disables
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamState(NamedTuple):
    """Optimizer state: step count plus first/second moment pytrees."""

    step: Array
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamState:
    """Zero-initialized AdamState shaped like ``params`` (f32 moments)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def schedule(step: Array, cfg: AdamConfig) -> Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def update(grads: PyTree, state: AdamState, params: PyTree,
           cfg: AdamConfig) -> tuple[PyTree, AdamState]:
    """One Adam(W) step: returns (new_params, new_state); pure and shardable."""
    step = state.step + 1
    if cfg.grad_clip > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v
                      + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = schedule(state.step, cfg)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# FunctionIntf form (Fig.4 testbed)
# ---------------------------------------------------------------------------

def adam_minimize(f: Function, key: Array, dim: int, max_evals: int = 100_000,
                  lr: float = 0.05, grad_mode: str = "richardson",
                  b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> OptimizeResult:
    """Budget-capped Adam on a FunctionIntf objective (Fig.4 protocol)."""
    lo, hi = f.lo, f.hi
    grad_fn = make_grad(f.fn, grad_mode)

    def run(key):
        x = jax.random.uniform(key, (dim,), minval=lo, maxval=hi)
        m = jnp.zeros_like(x)
        v = jnp.zeros_like(x)
        fx = f.fn(x)

        def cond(c):
            return c[-1] < max_evals

        def body(c):
            x, m, v, t, bx, bf, evals = c
            g, ge = grad_fn(x)
            t = t + 1
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            x = jnp.clip(x - lr * mh / (jnp.sqrt(vh) + eps), lo, hi)
            fx = f.fn(x)
            best = fx < bf
            return (x, m, v, t,
                    jnp.where(best, x, bx), jnp.where(best, fx, bf),
                    evals + ge + 1)

        out = jax.lax.while_loop(
            cond, body, (x, m, v, jnp.asarray(0.0), x, fx, jnp.asarray(1)))
        return out[4], out[5], out[6]

    bx, bf, ev = jax.jit(run)(key)
    return OptimizeResult(arg=bx, value=float(bf), n_evals=int(ev))
