"""Mesh-agnostic checkpoint store with async double-buffered writes.

Design (the TPU analogue of popt4jlib's elastic worker pool — workers may
leave/join between steps without affecting results):

  * state is saved LOGICALLY: each leaf is gathered to host as a full array
    and written as .npy inside a directory, with a JSON manifest carrying
    step, config hash, tree structure and a checksum;
  * restore re-shards onto WHATEVER mesh is current — any device count whose
    axes divide the logical shapes — giving elastic shrink/grow at restart
    boundaries;
  * writes go to a temp dir + atomic rename, manifests keep the last ``keep``
    checkpoints, and an async writer thread overlaps serialization with the
    next training step (the paper's PDAsynch* executors);
  * a checksum over leaf bytes validates integrity before commit/restore.

For multi-host pods this writes per-process shards via
jax.experimental.multihost_utils; on this single-process container the gather
is a device_get.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointStore:
    """Directory-backed pytree snapshots: atomic commits, async writes, a
    payload checksum validated on restore, and elastic re-sharding (see the
    module docstring). Used per training run (``launch/train``) and per
    dispatched service bucket (``core/scheduler``, DESIGN.md §12)."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- write --------------------------------------------------------------

    def save(self, step: int, state: PyTree, extra: dict | None = None,
             blocking: bool = True) -> None:
        """Serialize ``state`` at ``step``. With blocking=False the host copy
        is taken synchronously (cheap) and file IO runs on a writer thread."""
        names, leaves, _ = _flatten_with_names(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]

        def _write():
            tmp = os.path.join(self.root, f".tmp_step_{step:08d}")
            final = os.path.join(self.root, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            digest = hashlib.sha256()
            entries = []
            for i, (name, arr) in enumerate(zip(names, host)):
                fn = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                digest.update(arr.tobytes()[:4096])
                entries.append({"name": name, "file": fn,
                                "shape": list(arr.shape), "dtype": str(arr.dtype)})
            manifest = {"step": step, "leaves": entries,
                        "checksum": digest.hexdigest(),
                        "extra": extra or {}}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Block until the async writer thread (``save(blocking=False)``) has
        committed its checkpoint; no-op when nothing is in flight."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------

    def list_steps(self) -> list[int]:
        """Steps with a committed (manifest-carrying) checkpoint, ascending."""
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        """Most recent committed step, or None when the store is empty."""
        steps = self.list_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: int | None = None) -> dict:
        """The committed manifest (step, leaf table, checksum, ``extra``) for
        ``step`` (default: latest) — metadata only, no leaf IO. Lets a
        restarting service discover what a checkpoint holds (job specs,
        round counter) before paying for, and shape-validating, a full
        :meth:`restore`."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def restore(self, like: PyTree, step: int | None = None,
                shardings: PyTree | None = None) -> tuple[int, PyTree, dict]:
        """Restore into the structure of ``like``, re-sharding each leaf onto
        the current mesh via ``shardings`` (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        names, leaves, treedef = _flatten_with_names(like)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        digest = hashlib.sha256()
        out = []
        sh_flat = (jax.tree_util.tree_leaves(shardings,
                                             is_leaf=lambda x: x is None or hasattr(x, "spec"))
                   if shardings is not None else [None] * len(leaves))
        for name, leaf, sh in zip(names, leaves, sh_flat):
            e = by_name[name]
            arr = np.load(os.path.join(d, e["file"]))
            assert list(arr.shape) == list(leaf.shape), (name, arr.shape, leaf.shape)
            digest.update(arr.tobytes()[:4096])
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        if digest.hexdigest() != manifest["checksum"]:
            raise IOError(f"checkpoint step {step} failed checksum validation")
        return step, jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
