"""Benchmark test functions from popt4jlib §V.B (a)–(k).

All functions are pure-jnp, operate on a single (dim,) vector and are written to be
`vmap`-able over a population axis and differentiable where the underlying function
is (LND1–LND7 are nonsmooth by construction — subgradients via JAX where defined).

Definitions follow the classical (unshifted, unrotated) forms the paper uses, plus
the CEC'2008 shifted Rosenbrock used in §V.A. LND1–LND7 follow Haarala's
large-scale nonsmooth testbed [14]: MAXQ, MXHILB, Chained LQ, Chained CB3 I/II,
Number of Active Faces, Nonsmooth Generalized Brown 2.
"""
from __future__ import annotations

import dataclasses
import itertools
import weakref
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Stable identity tokens for objective callables (see fn_token). Weak
# references, so tokening an objective never extends its lifetime; the
# counter is monotonic, so a token value is never reused even after the
# callable is garbage-collected — unlike id(), which CPython recycles.
_FN_TOKENS: "weakref.WeakKeyDictionary[Callable, int]" = weakref.WeakKeyDictionary()
_FN_TOKEN_PINS: list[tuple[Callable, int]] = []   # non-weakref-able callables
_FN_TOKEN_COUNTER = itertools.count()


def fn_token(fn: Callable) -> int:
    """GC-stable identity token for an objective callable.

    Compiled-program caches (``core.executor``, ``core.islands``) key on the
    objective's identity; keying on ``id(fn)`` is unsound because CPython
    reuses addresses after garbage collection, which can silently serve a
    program compiled for a dead objective. Tokens are drawn from a monotonic
    counter and held via weak references, so two distinct callables can never
    share one — alive or dead. Callables that do not support weak references
    (rare: some builtins/partials) are pinned for the process lifetime.
    """
    try:
        tok = _FN_TOKENS.get(fn)
        if tok is None:
            tok = next(_FN_TOKEN_COUNTER)
            _FN_TOKENS[fn] = tok
        return tok
    except TypeError:
        for obj, tok in _FN_TOKEN_PINS:
            if obj is fn:
                return tok
        tok = next(_FN_TOKEN_COUNTER)
        _FN_TOKEN_PINS.append((fn, tok))
        return tok


@dataclasses.dataclass(frozen=True)
class Function:
    """popt4jlib ``FunctionIntf`` equivalent: a real-valued objective.

    ``fn`` maps a (dim,) vector -> scalar. ``lo``/``hi`` give the box domain used
    by the optimizers for initialization and clipping (the paper's methods are
    box-constrained searches).
    """

    name: str
    fn: Callable[[Array], Array]
    lo: float
    hi: float
    f_star: float = 0.0  # known global optimum value (for reporting only)
    smooth: bool = True
    # Kernel metadata: shifted/biased variants (CEC'2008) carry their offset so
    # the executor's ``pallas`` backend can pass it to the fused kernel, whose
    # registry entries implement only the canonical (unshifted) forms.
    shift: Array | None = dataclasses.field(default=None, compare=False)
    bias: float = 0.0

    def __call__(self, x: Array) -> Array:
        return self.fn(x)

    def eval_population(self, pop: Array) -> Array:
        """Evaluate a (P, dim) population -> (P,) fitness. The paper's distributed
        batch evaluation maps onto vmap (+ sharding at the engine level)."""
        return jax.vmap(self.fn)(pop)

    def cache_token(self) -> tuple:
        """Stable compiled-program cache key for this objective.

        ``(name, fn_token(fn), shift bytes, bias)`` — the callable's identity
        via :func:`fn_token` (never recycled, unlike ``id()``) and the shift
        by *content*, so two shifted variants sharing one base callable can
        never collide on a reused array address.
        """
        shift = None if self.shift is None else np.asarray(self.shift).tobytes()
        return (self.name, fn_token(self.fn), shift, self.bias)


# ---------------------------------------------------------------------------
# (a)–(j): smooth/classic benchmark functions
# ---------------------------------------------------------------------------

def ackley(x: Array) -> Array:
    d = x.shape[-1]
    s1 = jnp.sqrt(jnp.mean(x * x, axis=-1))
    s2 = jnp.mean(jnp.cos(2.0 * jnp.pi * x), axis=-1)
    return (-20.0 * jnp.exp(-0.2 * s1) - jnp.exp(s2) + 20.0 + jnp.e).astype(x.dtype)


def rastrigin(x: Array) -> Array:
    d = x.shape[-1]
    return 10.0 * d + jnp.sum(x * x - 10.0 * jnp.cos(2.0 * jnp.pi * x), axis=-1)


def rosenbrock(x: Array) -> Array:
    x0, x1 = x[..., :-1], x[..., 1:]
    return jnp.sum(100.0 * (x1 - x0 * x0) ** 2 + (1.0 - x0) ** 2, axis=-1)


def dropwave(x: Array) -> Array:
    # n-D generalization of the classic 2-D DropWave.
    s = jnp.sum(x * x, axis=-1)
    return -(1.0 + jnp.cos(12.0 * jnp.sqrt(s))) / (0.5 * s + 2.0)


def schwefel(x: Array) -> Array:
    d = x.shape[-1]
    return 418.9829 * d - jnp.sum(x * jnp.sin(jnp.sqrt(jnp.abs(x))), axis=-1)


def griewank(x: Array) -> Array:
    d = x.shape[-1]
    i = jnp.arange(1, d + 1, dtype=x.dtype)
    return jnp.sum(x * x, axis=-1) / 4000.0 - jnp.prod(jnp.cos(x / jnp.sqrt(i)), axis=-1) + 1.0


def trid(x: Array) -> Array:
    return jnp.sum((x - 1.0) ** 2, axis=-1) - jnp.sum(x[..., 1:] * x[..., :-1], axis=-1)


def michalewicz(x: Array, m: int = 10) -> Array:
    d = x.shape[-1]
    i = jnp.arange(1, d + 1, dtype=x.dtype)
    return -jnp.sum(jnp.sin(x) * jnp.sin(i * x * x / jnp.pi) ** (2 * m), axis=-1)


def sphere(x: Array) -> Array:
    return jnp.sum(x * x, axis=-1)


def levy(x: Array) -> Array:
    w = 1.0 + (x - 1.0) / 4.0
    wi = w[..., :-1]
    t1 = jnp.sin(jnp.pi * w[..., 0]) ** 2
    t2 = jnp.sum((wi - 1.0) ** 2 * (1.0 + 10.0 * jnp.sin(jnp.pi * wi + 1.0) ** 2),
                 axis=-1)
    wd = w[..., -1]
    t3 = (wd - 1.0) ** 2 * (1.0 + jnp.sin(2.0 * jnp.pi * wd) ** 2)
    return t1 + t2 + t3


def weierstrass(x: Array, a: float = 0.5, b: float = 3.0, kmax: int = 20) -> Array:
    d = x.shape[-1]
    k = jnp.arange(kmax + 1, dtype=x.dtype)
    ak = a ** k                      # (K,)
    bk = b ** k                      # (K,)
    inner = jnp.sum(ak * jnp.cos(2.0 * jnp.pi * bk * (x[..., None] + 0.5)), axis=-1)
    const = jnp.sum(ak * jnp.cos(jnp.pi * bk))  # 2*pi*b^k*0.5
    return jnp.sum(inner, axis=-1) - d * const


# ---------------------------------------------------------------------------
# (k): LND1–LND7 — Haarala's large-scale nonsmooth problems [14]
# ---------------------------------------------------------------------------

def lnd1_maxq(x: Array) -> Array:
    """MAXQ: max_i x_i^2."""
    return jnp.max(x * x, axis=-1)


def lnd2_mxhilb(x: Array) -> Array:
    """MXHILB: max_i |sum_j x_j / (i+j-1)|."""
    d = x.shape[-1]
    i = jnp.arange(1, d + 1)[:, None]
    j = jnp.arange(1, d + 1)[None, :]
    H = 1.0 / (i + j - 1.0)
    return jnp.max(jnp.abs(H.astype(x.dtype) @ x), axis=-1)


def lnd3_chained_lq(x: Array) -> Array:
    """Chained LQ: sum_i max{-x_i - x_{i+1}, -x_i - x_{i+1} + x_i^2 + x_{i+1}^2 - 1}."""
    a, b = x[..., :-1], x[..., 1:]
    t = -a - b
    return jnp.sum(jnp.maximum(t, t + a * a + b * b - 1.0), axis=-1)


def lnd4_chained_cb3_i(x: Array) -> Array:
    """Chained CB3 I: sum_i max of the three convex pieces."""
    a, b = x[..., :-1], x[..., 1:]
    p1 = a ** 4 + b * b
    p2 = (2.0 - a) ** 2 + (2.0 - b) ** 2
    p3 = 2.0 * jnp.exp(-a + b)
    return jnp.sum(jnp.maximum(jnp.maximum(p1, p2), p3), axis=-1)


def lnd5_chained_cb3_ii(x: Array) -> Array:
    """Chained CB3 II: max of the three summed pieces."""
    a, b = x[..., :-1], x[..., 1:]
    s1 = jnp.sum(a ** 4 + b * b, axis=-1)
    s2 = jnp.sum((2.0 - a) ** 2 + (2.0 - b) ** 2, axis=-1)
    s3 = jnp.sum(2.0 * jnp.exp(-a + b), axis=-1)
    return jnp.maximum(jnp.maximum(s1, s2), s3)


def lnd6_active_faces(x: Array) -> Array:
    """Number of Active Faces: max_i { g(-sum x), g(x_i) }, g(y)=ln(|y|+1)."""
    g = lambda y: jnp.log(jnp.abs(y) + 1.0)
    return jnp.maximum(jnp.max(g(x), axis=-1), g(-jnp.sum(x, axis=-1)))


def lnd7_brown2(x: Array) -> Array:
    """Nonsmooth generalized Brown function 2.

    sum_i |x_i|^{x_{i+1}^2+1} + |x_{i+1}|^{x_i^2+1}.  |x|^p computed via
    exp(p*log(|x|+eps)) for numeric stability at 0.
    """
    a, b = x[..., :-1], x[..., 1:]
    eps = jnp.asarray(1e-12, x.dtype)
    powa = jnp.exp((b * b + 1.0) * jnp.log(jnp.abs(a) + eps))
    powb = jnp.exp((a * a + 1.0) * jnp.log(jnp.abs(b) + eps))
    return jnp.sum(powa + powb, axis=-1)


# ---------------------------------------------------------------------------
# §V.A: CEC'2008 shifted Rosenbrock (F_bias = 390)
# ---------------------------------------------------------------------------

def shift_vector(dim: int, seed: int = 2008, lo: float = -90.0, hi: float = 90.0) -> Array:
    """Deterministic stand-in for the CEC'2008 shift data file (offline container)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (dim,), minval=lo, maxval=hi, dtype=jnp.float32)


def make_shifted_rosenbrock(dim: int, seed: int = 2008, bias: float = 390.0) -> Function:
    o = shift_vector(dim, seed)

    def fn(x: Array) -> Array:
        z = x - o.astype(x.dtype) + 1.0
        return rosenbrock(z) + jnp.asarray(bias, x.dtype)

    return Function("shifted_rosenbrock", fn, -100.0, 100.0, f_star=bias,
                    shift=o, bias=bias)


# ---------------------------------------------------------------------------
# Registry — the §V.B testbed (domains follow the classical definitions).
# ---------------------------------------------------------------------------

FUNCTIONS: dict[str, Function] = {
    "ackley": Function("ackley", ackley, -32.768, 32.768),
    "rastrigin": Function("rastrigin", rastrigin, -5.12, 5.12),
    "rosenbrock": Function("rosenbrock", rosenbrock, -100.0, 100.0),
    "dropwave": Function("dropwave", dropwave, -5.12, 5.12, f_star=-1.0),
    "schwefel": Function("schwefel", schwefel, -500.0, 500.0),
    "griewank": Function("griewank", griewank, -600.0, 600.0),
    "trid": Function("trid", trid, -100.0, 100.0, f_star=float("-inf")),
    "michalewicz": Function("michalewicz", michalewicz, 0.0, jnp.pi, f_star=float("-inf")),
    "sphere": Function("sphere", sphere, -100.0, 100.0),
    "levy": Function("levy", levy, -10.0, 10.0),
    "weierstrass": Function("weierstrass", weierstrass, -0.5, 0.5),
    "lnd1": Function("lnd1", lnd1_maxq, -10.0, 10.0, smooth=False),
    "lnd2": Function("lnd2", lnd2_mxhilb, -10.0, 10.0, smooth=False),
    "lnd3": Function("lnd3", lnd3_chained_lq, -10.0, 10.0, smooth=False),
    "lnd4": Function("lnd4", lnd4_chained_cb3_i, -10.0, 10.0, smooth=False),
    "lnd5": Function("lnd5", lnd5_chained_cb3_ii, -10.0, 10.0, smooth=False),
    "lnd6": Function("lnd6", lnd6_active_faces, -10.0, 10.0, smooth=False),
    "lnd7": Function("lnd7", lnd7_brown2, -1.0, 1.0, smooth=False),
}


def get(name: str, dim: int | None = None) -> Function:
    if name == "shifted_rosenbrock":
        assert dim is not None, "shifted_rosenbrock needs dim for its shift vector"
        return make_shifted_rosenbrock(dim)
    return FUNCTIONS[name]
