from repro.functions.benchmarks import (  # noqa: F401
    FUNCTIONS,
    Function,
    get,
    make_shifted_rosenbrock,
    shift_vector,
)
