"""gemma2-9b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8, head_dim=256) d_ff=14336 vocab=256000.
Sliding window 4096 on alternating layers; attn softcap 50, final softcap 30;
sandwich (pre+post) norms; GeGLU; embeddings scaled by sqrt(d_model).

long_500k is SKIPPED for this arch: global layers are full attention
(see DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    window=4096, local_global_pattern=True,
    attn_softcap=50.0, final_softcap=30.0, post_norm=True,
    activation="gelu", scale_embeddings=True, tie_embeddings=True,
    sharding_mode="tp+fsdp", remat_group=6,
)
