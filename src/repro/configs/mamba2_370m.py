"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1024 (attn-free, d_inner=2048, ssm_state=128, 32 heads of dim 64)
vocab=50280. Runs long_500k (O(1) decode state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    block_pattern="ssm",
    n_layers=48, d_model=1024, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
    sharding_mode="tp",
)
