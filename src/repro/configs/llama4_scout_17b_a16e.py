"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) per-expert d_ff=8192, 16 routed experts top-1
plus one shared expert, vocab=202048. ~109B total / ~17B active parameters.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    num_experts=16, top_k=1, moe_d_ff=8192, shared_expert_d_ff=8192,
    capacity_factor=1.25,
    activation="silu", rope_theta=500_000.0, tie_embeddings=False,
    sharding_mode="tp+fsdp", remat_group=12,
)
