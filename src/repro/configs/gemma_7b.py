"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (kv=16, head_dim=256) d_ff=24576 vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    activation="gelu", scale_embeddings=True, tie_embeddings=True,
    sharding_mode="tp+fsdp", remat_group=7,
)
