"""musicgen-medium [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
The EnCodec/codebook-interleaving frontend is a STUB: input_specs feeds
precomputed frame embeddings (the summed codebook embeddings, width 1536).
Sinusoidal positions, untied LM head over the 2048-entry codebook.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048,
    activation="gelu", pos_embedding="sinusoidal", tie_embeddings=False,
    frontend="audio_stub", frontend_dim=1536,
    vocab_pad_to=128,
    sharding_mode="tp+fsdp",  # attn weights replicated on model (24H): FSDP storage keeps moments sharded
)
