"""popt-bench — the paper's own production workload (§V.A, Table I):

single-island DDE on the CEC'2008 shifted Rosenbrock in 1000 dimensions,
population 800, 20000 generations, px=0.2, w=0.5, "non-determinism-ok".
On the production mesh the population axis shards over all devices (the
paper's distributed function-evaluation network).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PoptBenchConfig:
    dim: int = 1000
    pop: int = 800
    n_gens: int = 20_000
    w: float = 0.5
    px: float = 0.2
    strategy: str = "rand1bin"
    barrier_mode: str = "chunked"   # "non-determinism-ok" = true
    function: str = "shifted_rosenbrock"


CONFIG = PoptBenchConfig()
