"""popt-bench — the paper's own production workload (§V.A, Table I):

single-island DDE on the CEC'2008 shifted Rosenbrock in 1000 dimensions,
population 800, 20000 generations, px=0.2, w=0.5, "non-determinism-ok".
On the production mesh the population axis shards over all devices (the
paper's distributed function-evaluation network).

``HYBRID_CONFIG`` is the same workload with the memetic polish layer on
(DESIGN.md §6) — the paper's DDE+ASD-style hybrid: a sparse cadence and a
small top-k keep the polish share of the budget low, because one ASD event
in 1000-D costs ``steps * (4*1000 + 8)`` evaluations per polished point.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PoptBenchConfig:
    dim: int = 1000
    pop: int = 800
    n_gens: int = 20_000
    w: float = 0.5
    px: float = 0.2
    strategy: str = "rand1bin"
    barrier_mode: str = "chunked"   # "non-determinism-ok" = true
    function: str = "shifted_rosenbrock"
    # hybrid memetic layer (IslandConfig.polish*); "none" = plain DDE
    polish: str = "none"
    polish_every: int = 8
    polish_topk: int = 2
    polish_steps: int = 2


CONFIG = PoptBenchConfig()
HYBRID_CONFIG = PoptBenchConfig(polish="asd")
