"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model input.

Four shapes per LM architecture (40 cells):
  train_4k     seq_len=4096   global_batch=256   (train_step)
  prefill_32k  seq_len=32768  global_batch=32    (serve prefill)
  decode_32k   seq_len=32768  global_batch=128   (serve decode: 1 new token,
                                                  KV/SSM cache of seq_len)
  long_500k    seq_len=524288 global_batch=1     (long-context decode;
                                                  SSM/hybrid archs only)

``input_specs`` returns (step_kind, spec-pytree) where every leaf is a
jax.ShapeDtypeStruct — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import init_decode_state

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SUBQUADRATIC = ("ssm", "ssm+shared_attn")


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason when skipped."""
    if shape == "long_500k" and cfg.block_pattern not in SUBQUADRATIC:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (DESIGN.md §5)"
    return True, ""


def _token_batch(cfg: ModelConfig, B: int, S: int, with_labels: bool):
    """Token/embedding specs honouring the modality stubs."""
    batch: dict = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = SDS((B, S, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.frontend == "vlm_stub":
        batch["embeds"] = SDS((B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        batch["tokens"] = SDS((B, S - cfg.frontend_len), jnp.int32)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    if with_labels:
        batch["labels"] = SDS((B, S), jnp.int32)
    return batch


def decode_state_specs(cfg: ModelConfig, B: int, S: int):
    """ShapeDtypeStructs for the decode cache, mirroring init_decode_state."""
    state = jax.eval_shape(lambda: init_decode_state(cfg, B, S))
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), state)


def input_specs(cfg: ModelConfig, shape: str):
    """Returns (kind, specs) for the given cell. ``specs`` matches the step
    function signature for that kind (see launch/steps.py)."""
    sp = SHAPES[shape]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape} skipped: {why}")
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        return "train", {"batch": _token_batch(cfg, B, S, with_labels=True)}
    if sp.kind == "prefill":
        return "prefill", {"batch": _token_batch(cfg, B, S, with_labels=False)}
    # decode: one new token + a cache of length S
    new_tok: dict = {}
    if cfg.frontend == "audio_stub":
        new_tok["embeds"] = SDS((B, 1, cfg.frontend_dim), jnp.bfloat16)
    else:
        new_tok["tokens"] = SDS((B, 1), jnp.int32)
    return "decode", {"batch": new_tok, "state": decode_state_specs(cfg, B, S)}
