"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS: dict[str, str] = {
    "internvl2-2b": "repro.configs.internvl2_2b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "gemma-7b": "repro.configs.gemma_7b",
    "mamba2-370m": "repro.configs.mamba2_370m",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCHS}
