"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The ViT frontend is a
STUB per the assignment: input_specs feeds precomputed patch embeddings
(InternViT-300M output width 1024, 256 patch positions) through a projection.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553,
    activation="silu", rope_theta=1_000_000.0, tie_embeddings=True,
    frontend="vlm_stub", frontend_dim=1024, frontend_len=256,
    sharding_mode="tp",
)
