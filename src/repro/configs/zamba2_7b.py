"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 (d_inner=7168, ssm_state=64) with one shared attention block
(32H kv=32, d_ff=14336 MLP) applied every 6 Mamba2 layers (13 applications).
Runs long_500k (sub-quadratic backbone; the shared-attn KV cache is the only
attention state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    block_pattern="ssm+shared_attn", shared_attn_every=6,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    activation="gelu", tie_embeddings=True,
    sharding_mode="tp+fsdp", remat_group=6,
)
