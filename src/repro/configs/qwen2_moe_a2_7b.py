"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408, 60 routed experts top-4,
4 shared experts (fused: 4 x 1408 = 5632 hidden), vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    num_experts=60, top_k=4, moe_d_ff=1408, shared_expert_d_ff=5632,
    capacity_factor=1.25,
    activation="silu", tie_embeddings=False,
    sharding_mode="tp+fsdp", remat_group=4,
)
