"""The paper's technique applied to the ML substrate: island-model DE
optimizing the WEIGHTS of a micro-LM (gradient-free ES), with the LM loss
exposed through the library's FunctionIntf — the popt4jlib story
("any real-valued function") closed over the modern stack.

    PYTHONPATH=src python examples/es_lm_weights.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.data import SyntheticStream
from repro.functions import Function
from repro.models import init_params, loss_fn

cfg = dataclasses.replace(
    get_config("llama3.2-1b").reduced(),
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
    seq_len=16, global_batch=4, compute_dtype="float32", remat=False)

key = jax.random.PRNGKey(0)
p0 = init_params(key, cfg)
flat, tree = jax.tree_util.tree_flatten(p0)
sizes = [x.size for x in flat]
shapes = [x.shape for x in flat]
dim = sum(sizes)
print(f"micro-LM with {dim} weights as a {dim}-D FunctionIntf objective")

batch = {k: jnp.asarray(v) for k, v in next(iter(SyntheticStream(cfg))).items()}


def unflatten(x):
    out, off = [], 0
    for s, sh in zip(sizes, shapes):
        out.append(x[off:off + s].reshape(sh))
        off += s
    return jax.tree_util.tree_unflatten(tree, out)


def lm_loss(x):
    return loss_fn(unflatten(x), cfg, batch)[0]


f = Function("lm_loss", lm_loss, lo=-0.5, hi=0.5)
res = IslandOptimizer(
    ALGORITHMS["de"], IslandConfig(n_islands=2, pop=32, dim=dim,
                                   sync_every=5, migration="ring",
                                   max_evals=20_000),
    params={"strategy": "best1bin", "barrier_mode": "chunked"},
).minimize(f, key)

base = float(lm_loss(jnp.concatenate([x.ravel() for x in flat])))
print(f"init loss {base:.4f} (ln V = {jnp.log(cfg.vocab):.3f}) -> "
      f"ES-optimized {res.value:.4f} in {res.n_evals} evals")
