"""End-to-end LM training driver: a ~20M-parameter llama-family model trained
for a few hundred steps on the synthetic stream, with async checkpoints and a
mid-run restore drill (the fault-tolerance path exercised for real).

    PYTHONPATH=src python examples/lm_train.py --steps 300
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.launch.train import train
from repro.optim import adam


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/popt4jax_lm_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab=8192, seq_len=256, global_batch=8,
        remat=False, compute_dtype="float32", sharding_mode="tp",
        name="llama-mini-20m")

    acfg = adam.AdamConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

    # phase 1: train halfway, checkpointing
    half = args.steps // 2
    _, _, losses1 = train(cfg, steps=half, ckpt_dir=args.ckpt_dir,
                          ckpt_every=25, adam_cfg=acfg, log_every=25,
                          resume=False)
    print(f"\n-- simulated preemption at step {half}; restarting from the last "
          f"checkpoint (elastic restore path) --\n")
    # phase 2: restart resumes from the last committed checkpoint + data cursor
    _, _, losses2 = train(cfg, steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=25, adam_cfg=acfg, log_every=25,
                          resume=True)
    first = np.mean(losses1[:20])
    last = np.mean(losses2[-20:])
    print(f"\nloss: first-20 {first:.3f} -> last-20 {last:.3f} "
          f"({'OK: decreasing' if last < first else 'NOT decreasing'})")


if __name__ == "__main__":
    main()
