"""Hybrid memetic runs — DE+ASD three ways (DESIGN.md §6–§7).

1. In-scan hybrid: `IslandConfig.polish` runs a batched ASD polish of each
   island's best candidates inside the jitted round scan, on a cadence, with
   polish evaluations charged to the same budget as generation steps.
2. Two-stage pipeline: global explore to completion, then ONE batched polish
   dispatch over the final incumbents (`core.pipeline`).
3. Service: the same hybrid as a JSONL request — polish fields join the
   compiled shape-class, so hybrid jobs pack into their own bucket.

    PYTHONPATH=src python examples/hybrid_de_asd.py
"""
import jax
import jax.numpy as jnp

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, OptRequest,
                        ShapeBucketScheduler, explore_then_polish_many)
from repro.functions import get
from repro.optim import PolishConfig

DIM, BUDGET = 12, 12_000
f = get("rosenbrock")
key = jax.random.PRNGKey(0)
print(f"minimizing {f.name} in {DIM}-D at a {BUDGET}-eval budget (f* = 0)\n")

# -- plain DE baseline -------------------------------------------------------
base = dict(n_islands=2, pop=32, dim=DIM, sync_every=10, migration="ring",
            max_evals=BUDGET)
plain = IslandOptimizer(ALGORITHMS["de"], IslandConfig(**base)).minimize(f, key)
print(f"plain DE          best={plain.value:10.4f}  ({plain.n_evals} evals, "
      f"{plain.n_gens} gens)")

# -- 1. in-scan hybrid: DE interleaved with batched ASD polish ---------------
hybrid_cfg = IslandConfig(**base, polish="asd", polish_every=3,
                          polish_topk=2, polish_steps=2)
hybrid = IslandOptimizer(ALGORITHMS["de"], hybrid_cfg).minimize(f, key)
print(f"hybrid DE+ASD     best={hybrid.value:10.4f}  ({hybrid.n_evals} evals, "
      f"{hybrid.n_gens} gens — polish bought fewer gens, better f)")

# -- 2. two-stage pipeline: explore fully, then polish incumbents ------------
opt = IslandOptimizer(ALGORITHMS["de"], IslandConfig(**base))
keys = jnp.stack([jax.random.fold_in(key, s) for s in range(4)])
staged = explore_then_polish_many(opt, f, keys, PolishConfig(steps=12))
print(f"explore->polish   best={min(r.value for r in staged):10.4f}  "
      f"(4 jobs, 2 dispatches, {staged[0].n_evals} evals each)")

# -- 3. the same hybrid through the multi-job service ------------------------
sched = ShapeBucketScheduler()
ids = [sched.submit(OptRequest(fn="rosenbrock", algo="de", dim=DIM, pop=32,
                               n_islands=2, sync_every=10, max_evals=BUDGET,
                               polish="asd", polish_every=3, polish_topk=2,
                               polish_steps=2, seed=s))
       for s in range(4)]
sched.flush()                        # 4 hybrid jobs, ONE jitted dispatch
vals = [sched.result(i).result.value for i in ids]
print(f"service (4 jobs)  best={min(vals):10.4f}  "
      f"({sched.n_dispatches} dispatch, bit-identical to engine runs)")
