"""§V.A reproduction driver: single-island DDE on CEC'2008 shifted
Rosenbrock-1000 (pop 800, w=0.5, px=0.2, "non-determinism-ok").

Paper reference points: best value 2972.1 after 20000 generations (f*=390);
790.4 s single-threaded on a Xeon E5.

    PYTHONPATH=src python examples/distributed_de.py --gens 500     # quick
    PYTHONPATH=src python examples/distributed_de.py --gens 20000   # paper
"""
import argparse
import time

import jax

from repro.core import ALGORITHMS, ExecutorConfig, IslandConfig, IslandOptimizer
from repro.functions import make_shifted_rosenbrock


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=1000)
    ap.add_argument("--pop", type=int, default=800)
    ap.add_argument("--gens", type=int, default=500)
    ap.add_argument("--barrier", action="store_true",
                    help="enforce the determinism barrier (sync mode)")
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="evaluation backend for f(pop)")
    ap.add_argument("--fused", action="store_true",
                    help="run the whole DE generation in the fused Pallas "
                         "kernel (implies rand1bin; interpret mode off-TPU)")
    args = ap.parse_args()

    f = make_shifted_rosenbrock(args.dim)
    cfg = IslandConfig(n_islands=1, pop=args.pop, dim=args.dim,
                       migration="none", sync_every=10,
                       max_evals=args.pop * (args.gens + 1))
    params = {"w": 0.5, "px": 0.2,
              "barrier_mode": "sync" if args.barrier else "chunked"}
    if args.fused:
        params["fused"] = True
    opt = IslandOptimizer(
        ALGORITHMS["de"], cfg, params=params,
        exec_cfg=ExecutorConfig(backend=args.backend))
    t0 = time.time()
    res = opt.minimize(f, jax.random.PRNGKey(2008))
    wall = time.time() - t0
    mode = "fused" if args.fused else ("sync" if args.barrier else "chunked")
    print(f"DDE shifted-Rosenbrock d={args.dim} pop={args.pop} "
          f"gens={res.n_gens} mode={mode} backend={args.backend}")
    print(f"best = {res.value:.1f}   (paper: 2972.1 @20k gens, optimum 390)")
    print(f"wall = {wall:.1f}s  ({wall/max(res.n_gens,1)*1e3:.1f} ms/gen; "
          f"paper single-thread: 39.5 ms/gen)")


if __name__ == "__main__":
    main()
