"""Distributed DE driver — the §V.A reproduction, now shardable (DESIGN.md §8).

Default configuration is the paper's single-island DDE on CEC'2008 shifted
Rosenbrock-1000 (pop 800, w=0.5, px=0.2, "non-determinism-ok"); reference
points: best value 2972.1 after 20000 generations (f*=390), 790.4 s
single-threaded on a Xeon E5.

    PYTHONPATH=src python examples/distributed_de.py --gens 500     # quick
    PYTHONPATH=src python examples/distributed_de.py --gens 20000   # paper

``--islands N --devices D`` switches to the sharded island engine: N islands
with ring migration laid over D devices (``core.mesh.MeshConfig``), the round
scan under ``shard_map`` and migration as a ``lax.ppermute`` ring. On a
CPU-only machine the script forces D host-platform devices itself (the flag
must be set before jax initializes):

    PYTHONPATH=src python examples/distributed_de.py \
        --islands 8 --devices 8 --dim 64 --pop 128 --gens 500
"""
import argparse
import os
import time


def parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dim", type=int, default=1000)
    ap.add_argument("--pop", type=int, default=800)
    ap.add_argument("--gens", type=int, default=500)
    ap.add_argument("--islands", type=int, default=1,
                    help=">1 runs the island engine with ring migration")
    ap.add_argument("--devices", type=int, default=1,
                    help="devices the island axis shards over (DESIGN.md §8)")
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--barrier", action="store_true",
                    help="enforce the determinism barrier (sync mode)")
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="evaluation backend for f(pop)")
    ap.add_argument("--fused", action="store_true",
                    help="run the whole DE generation in the fused Pallas "
                         "kernel (implies rand1bin; interpret mode off-TPU)")
    return ap.parse_args()


def main(args: argparse.Namespace) -> None:
    import jax

    from repro.core import (ALGORITHMS, ExecutorConfig, IslandConfig,
                            IslandOptimizer, MeshConfig)
    from repro.functions import make_shifted_rosenbrock

    f = make_shifted_rosenbrock(args.dim)
    cfg = IslandConfig(
        n_islands=args.islands, pop=args.pop, dim=args.dim,
        migration="ring" if args.islands > 1 else "none",
        sync_every=args.sync_every,
        max_evals=args.islands * args.pop * (args.gens + 1))
    params = {"w": 0.5, "px": 0.2,
              "barrier_mode": "sync" if args.barrier else "chunked"}
    if args.fused:
        params["fused"] = True
    opt = IslandOptimizer(
        ALGORITHMS["de"], cfg, params=params,
        mesh_cfg=MeshConfig(devices=args.devices) if args.devices > 1 else None,
        exec_cfg=ExecutorConfig(backend=args.backend))
    t0 = time.time()
    res = opt.minimize(f, jax.random.PRNGKey(2008))
    wall = time.time() - t0
    mode = "fused" if args.fused else ("sync" if args.barrier else "chunked")
    gens = res.n_gens
    print(f"DDE shifted-Rosenbrock d={args.dim} pop={args.pop} "
          f"islands={args.islands} devices={args.devices} "
          f"gens={gens} mode={mode} backend={args.backend}")
    print(f"best = {res.value:.1f}   (paper: 2972.1 @20k gens, optimum 390)")
    print(f"wall = {wall:.1f}s  ({wall/max(gens,1)*1e3:.1f} ms/gen; "
          f"paper single-thread: 39.5 ms/gen)")


if __name__ == "__main__":
    _args = parse_args()
    _flag = "xla_force_host_platform_device_count"
    if _args.devices > 1 and _flag not in os.environ.get("XLA_FLAGS", ""):
        # Must land before jax initializes its backend, hence before main()'s
        # imports — harmless when real accelerators already provide devices.
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --{_flag}={_args.devices}").strip()
    main(_args)
