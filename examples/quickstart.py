"""Quickstart: minimize a benchmark function with three of the library's
island-model meta-heuristics and refine with conjugate gradient.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (ALGORITHMS, ExecutorConfig, IslandConfig,
                        IslandOptimizer, ObserverHub)
from repro.core.coupling import observed_local_search
from repro.functions import get

DIM = 12
f = get("rastrigin")
key = jax.random.PRNGKey(0)

print(f"minimizing {f.name} in {DIM}-D, box [{f.lo}, {f.hi}]  (f* = 0)\n")

# the Observer pattern: every new incumbent triggers an FCG local search
hub = ObserverHub()
observed_local_search(f, DIM, hub, budget_per_refine=2000)

for name in ("de", "pso", "sa"):
    cfg = IslandConfig(n_islands=4, pop=32, dim=DIM, sync_every=10,
                       migration="ring", max_evals=40_000)
    # rastrigin has a fused-kernel entry in kernels.registry, so the whole run
    # can use the Pallas evaluation backend (interpret mode off-TPU).
    res = IslandOptimizer(ALGORITHMS[name], cfg,
                          exec_cfg=ExecutorConfig(backend="pallas")).minimize(
        f, jax.random.fold_in(key, hash(name) % 1000))
    arg, val = hub.notify(res.arg, res.value)
    print(f"{name:4s} islands=4 best={res.value:10.4f} "
          f"after observer refine -> {val:10.4f}  ({res.n_evals} evals)")

print(f"\nglobal incumbent: {hub.best_val:.6f}")
