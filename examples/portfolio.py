"""Heterogeneous algorithm-portfolio islands — the paper's Fig.4 cooperation
scenario in one jitted scan (DESIGN.md §10).

1. Mixed portfolio: each island runs its OWN meta-heuristic (DE, PSO, SA
   cycled over the islands); the round loop dispatches per-island generation
   steps through ``lax.switch``, migration ships pos/fit between unlike
   policies (aux slots re-initialize on adoption), and the shared incumbent
   lets PSO islands exploit DE discoveries.
2. Homogeneous check: a portfolio of all-DE islands is bit-identical to the
   plain ``algo_maker`` engine — the determinism contract.
3. Service: the same portfolio as a JSONL request — the policy assignment
   joins the compiled shape-class, so portfolio jobs pack into their own
   bucket.

    PYTHONPATH=src python examples/portfolio.py
"""
import jax
import numpy as np

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, OptRequest,
                        ShapeBucketScheduler)
from repro.functions import get

DIM, BUDGET = 12, 18_000
f = get("rastrigin")
key = jax.random.PRNGKey(0)
print(f"minimizing {f.name} in {DIM}-D at a {BUDGET}-eval budget (f* = 0)\n")

base = dict(n_islands=6, pop=32, dim=DIM, sync_every=5, migration="ring",
            share_incumbent=True, max_evals=BUDGET)

# -- single-algorithm baselines ----------------------------------------------
for algo in ("de", "pso", "sa"):
    params = {"n_gens_hint": 90} if algo == "sa" else {}
    r = IslandOptimizer(ALGORITHMS[algo], IslandConfig(**base),
                        params=params).minimize(f, key)
    print(f"all-{algo:3s} islands   best={r.value:12.6f}  ({r.n_evals} evals)")

# -- 1. mixed DE+PSO+SA portfolio, same budget -------------------------------
cfg = IslandConfig(**base, portfolio=("de", "pso", "sa"))
port = IslandOptimizer(None, cfg,
                       params={"sa": {"n_gens_hint": 90}}).minimize(f, key)
print(f"de+pso+sa mix    best={port.value:12.6f}  ({port.n_evals} evals — "
      f"one lax.switch-dispatched scan)")

# -- 2. homogeneous portfolio == plain engine (determinism contract) ---------
plain = IslandOptimizer(ALGORITHMS["de"], IslandConfig(**base)).minimize(f, key)
homog = IslandOptimizer(None, IslandConfig(**base, portfolio=("de",))
                        ).minimize(f, key)
assert plain.value == homog.value
assert np.array_equal(np.asarray(plain.history), np.asarray(homog.history))
print(f"all-de portfolio best={homog.value:12.6f}  "
      f"(bit-identical to the plain engine)")

# -- 3. the same portfolio through the multi-job service ---------------------
sched = ShapeBucketScheduler()
ids = [sched.submit(OptRequest(fn="rastrigin", dim=DIM, pop=32, n_islands=6,
                               sync_every=5, share_incumbent=True,
                               max_evals=BUDGET,
                               portfolio=("de", "pso", "sa"),
                               params=(("sa", (("n_gens_hint", 90),)),),
                               seed=s))
       for s in range(4)]
sched.flush()                        # 4 portfolio jobs, ONE jitted dispatch
vals = [sched.result(i).result.value for i in ids]
print(f"service (4 jobs) best={min(vals):12.6f}  "
      f"({sched.n_dispatches} dispatch, portfolio bucket)")
