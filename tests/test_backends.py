"""EvalBackend layer + device-resident engine tests (ISSUE 1 acceptance).

Covers: XLA-vs-Pallas(interpret) fitness parity for every registered kernel,
device-resident vs host-stepped engine equivalence on a fixed seed, the
single-host-transfer property of the device-resident path, and the fused-DE
``step_override`` regression.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, ExecutorConfig, IslandConfig, IslandOptimizer, de
from repro.core.executor import make_batch_evaluator
from repro.functions import get, make_shifted_rosenbrock
from repro.kernels import registry

KEY = jax.random.PRNGKey(11)
SPHERE = get("sphere")


def _fn(name, dim):
    return make_shifted_rosenbrock(dim) if name == "shifted_rosenbrock" else get(name)


# --- backend parity ----------------------------------------------------------

@pytest.mark.parametrize("name", sorted(registry.registered()))
def test_xla_vs_pallas_parity(name):
    dim, P = 24, 65                       # deliberately unaligned shapes
    f = _fn(name, dim)
    pop = jax.random.uniform(jax.random.fold_in(KEY, hash(name) % 997), (P, dim),
                             minval=f.lo, maxval=f.hi)
    fx = make_batch_evaluator(f, ExecutorConfig(backend="xla"))(pop)
    fp = make_batch_evaluator(f, ExecutorConfig(backend="pallas"))(pop)
    rel = float(jnp.max(jnp.abs(fx - fp) / (jnp.abs(fx) + 1.0)))
    assert rel <= 1e-4, (name, rel)


def test_pallas_backend_unregistered_function_raises():
    with pytest.raises(KeyError, match="weierstrass"):
        make_batch_evaluator(get("weierstrass"), ExecutorConfig(backend="pallas"))


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        make_batch_evaluator(SPHERE, ExecutorConfig(backend="cuda"))


def test_pallas_backend_retry_semantics():
    """The resubmit-once/evict policy is backend-independent: the pallas path
    keeps finite fitness finite and shapes intact."""
    f = get("rastrigin")
    ev = make_batch_evaluator(f, ExecutorConfig(backend="pallas", retry_bad=True))
    pop = jax.random.uniform(KEY, (13, 8), minval=f.lo, maxval=f.hi)
    fit = ev(pop)
    assert fit.shape == (13,) and bool(jnp.all(jnp.isfinite(fit)))


def test_pallas_backend_under_island_engine():
    cfg = IslandConfig(n_islands=2, pop=16, dim=8, sync_every=5, max_evals=4000)
    res = IslandOptimizer(ALGORITHMS["de"], cfg,
                          exec_cfg=ExecutorConfig(backend="pallas")
                          ).minimize(get("rastrigin"), KEY)
    assert np.isfinite(res.value)
    assert res.value < 10.0 * 8 * 2      # far below random-uniform expectation


# --- device-resident engine --------------------------------------------------

def test_device_resident_matches_host_stepped():
    """Same seed -> the single-scan device program and the per-round host loop
    produce the same incumbent trace and final value."""
    cfg = IslandConfig(n_islands=2, pop=16, dim=4, sync_every=5, max_evals=4000)
    r_dev = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    rounds = []
    r_host = IslandOptimizer(
        ALGORITHMS["de"], cfg,
        round_callback=lambda r, a, v: rounds.append(r),
    ).minimize(SPHERE, KEY)
    assert len(rounds) == len(r_host.history) == len(np.asarray(r_dev.history))
    np.testing.assert_allclose(np.asarray(r_dev.history),
                               np.asarray(r_host.history), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r_dev.value, r_host.value, rtol=1e-5, atol=1e-5)


def test_device_resident_single_host_transfer(monkeypatch):
    """No round_callback -> results cross host<->device exactly once."""
    pulls = {"n": 0}
    real = jax.device_get

    def counting(x):
        pulls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    cfg = IslandConfig(n_islands=2, pop=16, dim=4, sync_every=5, max_evals=4000)
    res = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    assert pulls["n"] == 1
    assert np.isfinite(res.value) and len(res.history) > 1


def test_device_resident_history_on_device_buffer():
    cfg = IslandConfig(n_islands=1, pop=16, dim=4, migration="none",
                       max_evals=3200)
    res = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    hist = np.asarray(res.history)
    n_rounds = (cfg.max_evals - 16) // (16 * cfg.sync_every)
    assert hist.shape == (n_rounds,)
    assert np.all(hist[1:] <= hist[:-1] + 1e-9)


# --- fused DE (step_override) ------------------------------------------------

def test_fused_de_one_generation_matches_xla():
    f = get("sphere")
    pop, dim = 24, 16
    ev = make_batch_evaluator(f, ExecutorConfig())
    plain = de.make(f=f, evaluator=ev, pop=pop, dim=dim)
    fused = de.make(f=f, evaluator=ev, pop=pop, dim=dim, fused=True)
    assert fused.step_override is not None and plain.step_override is None
    state = plain.init(jax.random.fold_in(KEY, 1))
    gk = jax.random.fold_in(KEY, 2)
    s_plain = plain.gen(dict(state), gk)
    s_fused = fused.step_override(dict(state), gk)
    np.testing.assert_allclose(s_plain["fit"], s_fused["fit"], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_plain["pop"], s_fused["pop"], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_plain["best_val"], s_fused["best_val"],
                               rtol=1e-4, atol=1e-4)


def test_fused_de_runs_under_island_engine():
    """`de.make(..., fused=True)` under the engine on CPU (interpret mode)."""
    f = get("rastrigin")
    cfg = IslandConfig(n_islands=2, pop=24, dim=8, sync_every=5, max_evals=6000)
    r1 = IslandOptimizer(ALGORITHMS["de"], cfg, params={"fused": True}).minimize(f, KEY)
    r2 = IslandOptimizer(ALGORITHMS["de"], cfg, params={"fused": True}).minimize(f, KEY)
    assert r1.value == r2.value          # deterministic
    assert np.isfinite(r1.value)
    hist = np.asarray(r1.history)
    assert np.all(hist[1:] <= hist[:-1] + 1e-9)
    assert r1.value < 10.0 * 8 * 2


def test_fused_de_shifted_rosenbrock():
    """Fused path honors the CEC'2008 shift/bias carried on the Function."""
    f = make_shifted_rosenbrock(16)
    cfg = IslandConfig(n_islands=1, pop=32, dim=16, migration="none",
                       max_evals=20_000)
    res = IslandOptimizer(ALGORITHMS["de"], cfg,
                          params={"w": 0.5, "px": 0.2, "fused": True}).minimize(f, KEY)
    assert res.value >= 390.0 - 1e-3     # f* = 390 — bias must be applied
    assert res.value < 1e7


def test_fused_de_rejects_best1bin_and_unregistered():
    ev = make_batch_evaluator(SPHERE, ExecutorConfig())
    with pytest.raises(AssertionError):
        de.make(f=SPHERE, evaluator=ev, pop=8, dim=4, fused=True,
                strategy="best1bin")
    wf = get("weierstrass")
    with pytest.raises(KeyError):
        de.make(f=wf, evaluator=make_batch_evaluator(wf, ExecutorConfig()),
                pop=8, dim=4, fused=True)


# --- GC-stable compiled-program cache keys -----------------------------------

def test_fn_token_is_stable_and_never_recycled():
    """fn_token replaces id() in cache keys: stable per live callable, unique
    across callables, and never reused after GC (the id()-recycling hazard
    that could silently serve a stale compiled program)."""
    import gc
    from repro.functions.benchmarks import fn_token

    def f(x):
        return x

    def g(x):
        return x

    assert fn_token(f) == fn_token(f)
    assert fn_token(f) != fn_token(g)
    dead_tok = fn_token(g)
    del g
    gc.collect()

    def h(x):
        return x

    assert fn_token(h) != dead_tok           # monotonic counter, no recycling


def test_cache_token_keys_on_shift_content():
    """Two objectives sharing one callable but carrying different shifts must
    key differently — the id(shift)-reuse case that used to be able to serve
    a program compiled for the wrong shift."""
    import dataclasses
    f1 = make_shifted_rosenbrock(6, seed=1)
    f2 = dataclasses.replace(f1, shift=f1.shift + 1.0)
    assert f1.cache_token() != f2.cache_token()
    assert f1.cache_token() == f1.cache_token()
    # and the evaluator cache respects it: different shifts, different
    # compiled pallas programs (same callable identity either way)
    cfg = ExecutorConfig(backend="pallas")
    e1 = make_batch_evaluator(f1, cfg)
    e2 = make_batch_evaluator(f2, cfg)
    assert e1 is not e2
    assert make_batch_evaluator(f1, cfg) is e1   # and still memoizes


def test_single_optimizer_run_cache_hits_across_calls():
    """IslandOptimizer's per-objective program cache: same Function object ->
    cached jitted run; equal-content clone -> same token class but distinct
    fn identity, so it rebuilds instead of serving the stale closure."""
    f = get("sphere", 4)
    cfg = IslandConfig(n_islands=2, pop=8, dim=4, sync_every=2, max_evals=600)
    opt = IslandOptimizer(ALGORITHMS["de"], cfg)
    r1 = opt.minimize(f, KEY)
    n_cached = len(opt._many_cache)
    r2 = opt.minimize(f, KEY)
    assert len(opt._many_cache) == n_cached  # second call reused the program
    assert r1.value == r2.value


# --- fused PSO/GA/SA + eval_select (ISSUE 6) ---------------------------------

from repro.core import ga, pso, sa  # noqa: E402


@pytest.mark.parametrize("mod,keys", [
    (pso, ("pop", "fit", "vel", "pbest", "pbest_f", "best_val")),
    (ga, ("pop", "fit", "age", "alive", "best_val")),
    (sa, ("pop", "fit", "t", "best_val")),
])
def test_fused_one_generation_matches_xla(mod, keys):
    """Same key, same state -> the fused whole-generation kernel reproduces
    the plain XLA gen bit-for-bit up to f32 summation noise (mirrors the
    fused-DE regression above)."""
    f = get("rastrigin")
    pop, dim = 24, 16
    ev = make_batch_evaluator(f, ExecutorConfig())
    plain = mod.make(f=f, evaluator=ev, pop=pop, dim=dim)
    fused = mod.make(f=f, evaluator=ev, pop=pop, dim=dim, fused=True)
    assert fused.step_override is not None and plain.step_override is None
    state = plain.init(jax.random.fold_in(KEY, 3))
    gk = jax.random.fold_in(KEY, 4)
    s_plain = plain.gen(dict(state), gk)
    s_fused = fused.step_override(dict(state), gk)
    assert set(s_plain) == set(s_fused) >= set(keys)
    for k in keys:
        np.testing.assert_allclose(
            np.asarray(s_plain[k], np.float32), np.asarray(s_fused[k], np.float32),
            rtol=1e-4, atol=1e-4, err_msg=f"{mod.__name__}:{k}")


@pytest.mark.parametrize("algo", ["pso", "ga", "sa"])
def test_fused_policy_runs_under_island_engine(algo):
    f = get("rastrigin")
    cfg = IslandConfig(n_islands=2, pop=24, dim=8, sync_every=5, max_evals=6000)
    r1 = IslandOptimizer(ALGORITHMS[algo], cfg, params={"fused": True}).minimize(f, KEY)
    r2 = IslandOptimizer(ALGORITHMS[algo], cfg, params={"fused": True}).minimize(f, KEY)
    assert r1.value == r2.value          # deterministic
    assert np.isfinite(r1.value)
    hist = np.asarray(r1.history)
    assert np.all(hist[1:] <= hist[:-1] + 1e-9)


def test_fused_portfolio_under_lax_switch():
    """Heterogeneous portfolio where every branch is a fused kernel: the
    step_override path must survive lax.switch tracing and stay deterministic."""
    f = get("rastrigin")
    cfg = IslandConfig(n_islands=3, pop=16, dim=8, sync_every=5, max_evals=4800,
                       portfolio=("de", "pso", "sa"))
    fused_params = {"de": {"fused": True}, "pso": {"fused": True},
                    "sa": {"fused": True}}
    r1 = IslandOptimizer(None, cfg, params=fused_params).minimize(f, KEY)
    r2 = IslandOptimizer(None, cfg, params=fused_params).minimize(f, KEY)
    assert r1.value == r2.value
    assert np.isfinite(r1.value) and r1.value < 10.0 * 8 * 2


def test_executor_kernel_config_threads_to_pallas_backend():
    """ExecutorConfig.kernel pins the eval kernel's tiling; a pinned config
    and the autotuned default must agree numerically."""
    from repro.kernels import KernelConfig
    f = get("rastrigin")
    pop = jax.random.uniform(jax.random.fold_in(KEY, 21), (37, 12),
                             minval=f.lo, maxval=f.hi)
    pinned = make_batch_evaluator(
        f, ExecutorConfig(backend="pallas",
                          kernel=KernelConfig(pop_block=8, dim_pad=128)))(pop)
    auto = make_batch_evaluator(f, ExecutorConfig(backend="pallas"))(pop)
    np.testing.assert_allclose(np.asarray(pinned), np.asarray(auto),
                               rtol=1e-6, atol=1e-6)
