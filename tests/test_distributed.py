"""Distributed island layer tests (DESIGN.md §8).

Two tiers, matching the determinism contract:

* 1-device-mesh tests run everywhere (tier-1): the ``shard_map`` program on a
  degenerate mesh must be bit-identical to the unsharded engine.
* 8-host-device tests (``ppermute`` ring vs the host-side roll reference,
  sharded engine vs unsharded, sharded scheduler buckets) skip unless the
  process sees >= 8 devices — CI's distributed-smoke job provides them with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; conftest.py
  deliberately does NOT force them for the rest of the suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, MeshConfig,
                        OptRequest, ShapeBucketScheduler)
from repro.core import mesh as mesh_mod
from repro.core import migration
from repro.functions import get

KEY = jax.random.PRNGKey(7)
N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _cfg(**kw):
    base = dict(n_islands=4, pop=16, dim=6, sync_every=5, migration="ring",
                max_evals=4000)
    base.update(kw)
    return IslandConfig(**base)


def _minimize(algo, cfg, f, mesh_cfg=None, key=KEY):
    return IslandOptimizer(ALGORITHMS[algo], cfg,
                           mesh_cfg=mesh_cfg).minimize(f, key)


def _assert_same(a, b):
    """Bit-identical OptimizeResults: value, accounting, arg and history."""
    assert a.value == b.value
    assert a.n_evals == b.n_evals and a.n_gens == b.n_gens
    assert np.array_equal(np.asarray(a.arg), np.asarray(b.arg))
    assert np.array_equal(np.asarray(a.history), np.asarray(b.history))


# --- determinism contract: 1-device mesh == unsharded engine (tier-1) -------

@pytest.mark.parametrize("algo", ["de", "ga", "pso"])
def test_one_device_mesh_bit_identical(algo):
    f = get("rastrigin", 6)
    cfg = _cfg(migration="starvation" if algo == "ga" else "ring")
    _assert_same(_minimize(algo, cfg, f),
                 _minimize(algo, cfg, f, mesh_cfg=MeshConfig(devices=1)))


def test_one_device_mesh_share_incumbent_and_polish_bit_identical():
    f = get("rosenbrock", 6)
    cfg = _cfg(share_incumbent=True, max_evals=6000,
               polish="asd", polish_every=2, polish_topk=2, polish_steps=2)
    _assert_same(_minimize("de", cfg, f),
                 _minimize("de", cfg, f, mesh_cfg=MeshConfig(devices=1)))


def test_one_device_mesh_minimize_many_bit_identical():
    f = get("sphere", 6)
    cfg = _cfg()
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 3, 11)])
    plain = IslandOptimizer(ALGORITHMS["de"], cfg).minimize_many(f, keys)
    shard = IslandOptimizer(ALGORITHMS["de"], cfg,
                            mesh_cfg=MeshConfig(devices=1)).minimize_many(f, keys)
    for a, b in zip(plain, shard):
        _assert_same(a, b)


# --- migration primitives: sharded forms vs host-side references ------------

@needs8
@pytest.mark.parametrize("devices", [4, 8])   # islands/shard = 2 and 1
def test_ppermute_ring_matches_host_ring(devices):
    I, P, D, k = 8, 6, 4, 2
    kp, kf = jax.random.split(KEY)
    pop = jax.random.uniform(kp, (I, P, D), minval=-1.0, maxval=1.0)
    fit = jax.random.uniform(kf, (I, P), minval=0.0, maxval=9.0)
    ref_pop, ref_fit = migration.ring(pop, fit, k=k)

    mc = MeshConfig(devices=devices)
    sharded = mesh_mod.shard_map(
        lambda p, f: migration.ring(p, f, k=k, axis=mc.axis, n_shards=devices),
        mc.build(), in_specs=(PS(mc.axis), PS(mc.axis)),
        out_specs=(PS(mc.axis), PS(mc.axis)))
    got_pop, got_fit = sharded(pop, fit)
    assert np.array_equal(np.asarray(got_pop), np.asarray(ref_pop))
    assert np.array_equal(np.asarray(got_fit), np.asarray(ref_fit))


@needs8
def test_allgather_starvation_matches_host():
    I, P, D = 8, 10, 3
    kp, kf = jax.random.split(KEY)
    pop = jax.random.uniform(kp, (I, P, D), minval=-1.0, maxval=1.0)
    fit = jax.random.uniform(kf, (I, P), minval=0.0, maxval=9.0)
    # starve island 5: mark most of its population dead (+inf fitness)
    fit = fit.at[5, 1:].set(jnp.inf)
    ref_pop, ref_fit = migration.starvation(pop, fit, k=2)

    mc = MeshConfig(devices=8)
    sharded = mesh_mod.shard_map(
        lambda p, f: migration.starvation(p, f, k=2, axis=mc.axis, n_shards=8),
        mc.build(), in_specs=(PS(mc.axis), PS(mc.axis)),
        out_specs=(PS(mc.axis), PS(mc.axis)))
    got_pop, got_fit = sharded(pop, fit)
    assert np.array_equal(np.asarray(got_pop), np.asarray(ref_pop))
    assert np.array_equal(np.asarray(got_fit), np.asarray(ref_fit))


# --- sharded engine end-to-end (8 host devices) ------------------------------

@needs8
@pytest.mark.parametrize("mig,share", [("ring", False), ("starvation", False),
                                       ("ring", True)])
def test_eight_device_engine_matches_unsharded(mig, share):
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=8, migration=mig, share_incumbent=share,
               max_evals=8000)
    _assert_same(_minimize("de", cfg, f),
                 _minimize("de", cfg, f, mesh_cfg=MeshConfig(devices=8)))


@needs8
def test_eight_device_minimize_many_matches_sequential():
    f = get("levy", 6)
    cfg = _cfg(n_islands=8, max_evals=6000)
    seeds = (0, 4, 9)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    opt = IslandOptimizer(ALGORITHMS["de"], cfg, mesh_cfg=MeshConfig(devices=8))
    many = opt.minimize_many(f, keys)
    for s, got in zip(seeds, many):
        _assert_same(_minimize("de", cfg, f, key=jax.random.PRNGKey(s)), got)


@needs8
def test_scheduler_runs_sharded_bucket():
    """devices=8 jobs run in their own bucket and stay bit-identical to
    standalone sharded minimize; single-device traffic is undisturbed."""
    base = dict(fn="rastrigin", algo="de", dim=6, pop=16, n_islands=8,
                sync_every=5, max_evals=6000, migration="ring")
    sched = ShapeBucketScheduler()
    sharded_ids = [sched.submit(OptRequest(seed=s, devices=8, **base))
                   for s in (0, 2)]
    plain_id = sched.submit(OptRequest(seed=0, **base))
    assert len(sched.pending_buckets()) == 2
    assert sched.flush() == 3
    assert sched.n_dispatches == 2
    cfg = _cfg(n_islands=8, max_evals=6000)
    f = get("rastrigin", 6)
    for jid, seed in zip(sharded_ids, (0, 2)):
        got = sched.result(jid)
        assert got.status == "done"
        expect = _minimize("de", cfg, f, mesh_cfg=MeshConfig(devices=8),
                           key=jax.random.PRNGKey(seed))
        assert got.result.value == expect.value
        assert np.array_equal(np.asarray(got.result.arg),
                              np.asarray(expect.arg))
    assert sched.result(plain_id).status == "done"


# --- request plumbing and validation (device-count independent) -------------

def test_devices_joins_shape_class():
    a = OptRequest(fn="sphere", n_islands=8, devices=1)
    b = OptRequest(fn="sphere", n_islands=8, devices=8)
    assert a.shape_class() != b.shape_class()
    assert (OptRequest(fn="sphere", n_islands=8, devices=8, seed=0).shape_class()
            == OptRequest(fn="sphere", n_islands=8, devices=8, seed=5).shape_class())
    # JSONL requests pass the field through unchanged
    assert OptRequest.from_dict({"fn": "sphere", "devices": 4}).devices == 4


def test_unplaceable_devices_error_is_isolated_per_bucket():
    sched = ShapeBucketScheduler()
    bad = sched.submit(OptRequest(fn="sphere", dim=4, pop=16, n_islands=4,
                                  max_evals=1000, devices=4096))
    ok = sched.submit(OptRequest(fn="sphere", dim=4, pop=16, max_evals=1000))
    sched.flush()
    assert sched.poll(bad).status == "error"
    assert "devices" in sched.poll(bad).error
    assert sched.poll(ok).status == "done"


def test_meshconfig_validation():
    with pytest.raises(ValueError, match="devices"):
        MeshConfig(devices=0).build()
    with pytest.raises(ValueError, match="visible"):
        MeshConfig(devices=100_000).build()
    with pytest.raises(ValueError, match="multiple"):
        MeshConfig(devices=3).local_islands(4)
    assert MeshConfig(devices=2).local_islands(8) == 4
    assert mesh_mod.ring_perm(3) == [(0, 1), (1, 2), (2, 0)]


def test_island_optimizer_rejects_bad_sharding_configs():
    f = get("sphere", 4)
    with pytest.raises(ValueError, match="n_islands > 1"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(n_islands=1, migration="none"),
                        mesh_cfg=MeshConfig(devices=1))
    with pytest.raises(ValueError, match="multiple"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(n_islands=4),
                        mesh_cfg=MeshConfig(devices=3))
    with pytest.raises(ValueError, match="mutually exclusive"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(),
                        mesh=mesh_mod.MeshConfig(devices=1).build(),
                        mesh_cfg=MeshConfig(devices=1))
    opt = IslandOptimizer(ALGORITHMS["de"], _cfg(),
                          mesh_cfg=MeshConfig(devices=1),
                          round_callback=lambda r, a, v: None)
    with pytest.raises(ValueError, match="round_callback"):
        opt.minimize(f, KEY)
