"""Checkpoint store: roundtrip, async writes, GC, checksum, data-cursor resume,
and restart-equivalence of training (the fault-tolerance contract)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import SyntheticStream
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adam

KEY = jax.random.PRNGKey(2)


def _state():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32), "d": jnp.zeros((2, 2))}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    st = _state()
    store.save(7, st, extra={"data": {"step": 7, "seed": 17}})
    step, restored, extra = store.restore(st)
    assert step == 7 and extra["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_async_write_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        store.save(s, st, blocking=False)
    store.wait()
    store.save(5, st, blocking=True)
    assert store.list_steps() == [4, 5]          # GC kept the last 2


def test_checksum_validation(tmp_path):
    store = CheckpointStore(str(tmp_path))
    st = _state()
    store.save(1, st)
    # corrupt a leaf
    d = os.path.join(str(tmp_path), "step_00000001")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))
    np.save(os.path.join(d, fn), arr + 1.0)
    with pytest.raises(IOError):
        store.restore(st)


def test_train_restart_equivalence(tmp_path):
    """Train A: 8 steps straight. Train B: 4 steps, checkpoint, restore, 4 more.
    Both must land on identical params (bitwise restart contract)."""
    cfg = get_config("llama3.2-1b").reduced()
    acfg = adam.AdamConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    step = jax.jit(make_train_step(cfg, acfg))

    def run(n, params, opt, stream):
        for _ in range(n):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            params, opt, _ = step(params, opt, batch)
        return params, opt

    p0 = init_params(KEY, cfg)
    o0 = adam.init(p0)

    pa, oa = run(8, p0, o0, SyntheticStream(cfg))

    store = CheckpointStore(str(tmp_path))
    sb = SyntheticStream(cfg)
    pb, ob = run(4, p0, o0, sb)
    store.save(4, (pb, ob), extra={"data": sb.state_dict()})
    _, (pr, orr), extra = store.restore((pb, ob))
    sb2 = SyntheticStream(cfg)
    sb2.load_state_dict(extra["data"])
    pb2, _ = run(4, pr, orr, sb2)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
