"""Roofline-driven kernel autotuner (ISSUE 6): model sanity, VMEM
feasibility, per-shape-class cache determinism, and GC-stable function keys."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.functions import get, make_shifted_rosenbrock
from repro.kernels import autotune as at
from repro.kernels.autotune import KernelConfig
from repro.parallel import roofline as rl
from repro.parallel.memmodel import pallas_tile_bytes


@pytest.fixture(autouse=True)
def _fresh_cache():
    at.clear_cache()
    yield
    at.clear_cache()


# --- the model ---------------------------------------------------------------

def test_predict_roofline_terms_consistent():
    p = at.predict("de_step", 128, 1000, pop_block=64, dim_pad=1024,
                   tag="rastrigin")
    r = p.roofline
    assert isinstance(r, rl.Roofline)
    assert r.flops > 0 and r.hbm_bytes > 0
    assert r.t_compute == pytest.approx(r.flops / at.PEAK_FLOPS_BF16)
    assert r.t_memory == pytest.approx(r.hbm_bytes / at.HBM_BW)
    assert r.bottleneck in ("compute", "memory")
    assert p.t_total >= max(r.t_compute, r.t_memory)
    assert p.n_grid == 2 and p.tile_bytes == r.peak_bytes


def test_predict_interpret_penalizes_grid_steps():
    """Interpret mode pays per grid step, so a finer tiling of the same
    problem must cost strictly more than one big tile."""
    fine = at.predict("bench_eval", 1024, 128, 8, 128, interpret=True)
    coarse = at.predict("bench_eval", 1024, 128, 1024, 128, interpret=True)
    assert fine.n_grid == 128 and coarse.n_grid == 1
    assert fine.t_total > coarse.t_total


def test_candidates_bounded_and_aligned():
    for b, d in at.candidates(37, 100):
        assert b % 8 == 0 and b <= 40
        assert d % 128 == 0 and d >= 100
    assert (40, 128) in at.candidates(37, 100)


def test_pallas_tile_bytes_model():
    # 3 vec tiles of 8x128 f32, double-buffered, + 2 row vecs + 1 bcast row
    got = pallas_tile_bytes(3, 8, 128, n_row=2, n_bcast=1, itemsize=4)
    assert got == (2 * (3 * 8 * 128 + 2 * 8) + 128) * 4
    assert pallas_tile_bytes(1, 8, 128, double_buffered=False) == 8 * 128 * 4


def test_vmem_infeasible_configs_rejected():
    """A tile that cannot fit VMEM must never be chosen when any feasible
    candidate exists."""
    cfg = at.choose("pso_step", 4096, 8192, "sphere", interpret=False)
    pred = at.predict("pso_step", 4096, 8192, cfg.pop_block, cfg.dim_pad)
    assert pred.feasible


# --- the cache ---------------------------------------------------------------

def test_choose_deterministic_and_cached():
    c1 = at.choose("de_step", 128, 1000, "rastrigin")
    s1 = at.cache_stats()
    c2 = at.choose("de_step", 128, 1000, "rastrigin")
    s2 = at.cache_stats()
    assert c1 == c2 and isinstance(c1, KernelConfig)
    assert c1.pop_block is not None and c1.dim_pad is not None
    assert s1["misses"] == 1 and s2 == {**s1, "hits": s1["hits"] + 1}
    # distinct shape-class -> a fresh tune, not a stale hit
    c3 = at.choose("de_step", 256, 1000, "rastrigin")
    assert at.cache_stats()["misses"] == 2
    assert isinstance(c3, KernelConfig)


def test_choose_unknown_kind_raises():
    with pytest.raises(KeyError, match="unknown kernel kind"):
        at.choose("warp_drive", 8, 8)


def test_choose_for_keys_on_cache_token():
    """Same objective twice -> one tune then hits; an equal-content clone has
    a different cache_token and must re-key rather than alias."""
    f = make_shifted_rosenbrock(16, seed=3)
    c1 = at.choose_for(f, "de_step", 64, 16)
    assert at.cache_stats()["misses"] == 1
    c2 = at.choose_for(f, "de_step", 64, 16)
    assert c1 == c2 and at.cache_stats()["hits"] == 1
    clone = dataclasses.replace(f, shift=f.shift + 1.0)
    n_keys = len(at._FN_CACHE)
    at.choose_for(clone, "de_step", 64, 16)
    # the clone re-keys the per-objective memo (its shape-class config may
    # still be served from the shared kind/P/D cache — that's fine)
    assert len(at._FN_CACHE) == n_keys + 1


def test_choose_for_unregistered_function_raises():
    with pytest.raises(KeyError, match="weierstrass"):
        at.choose_for(get("weierstrass"), "de_step", 8, 8)


def test_resolve_explicit_fields_win():
    full = at.resolve(KernelConfig(pop_block=16, dim_pad=256, interpret=True),
                      "bench_eval", 37, 100)
    assert full == KernelConfig(pop_block=16, dim_pad=256, interpret=True)
    part = at.resolve(KernelConfig(pop_block=16), "bench_eval", 37, 100,
                      interpret=True)
    assert part.pop_block == 16 and part.dim_pad is not None
    assert part.interpret is True


def test_merge_overlay_precedence():
    base = KernelConfig(pop_block=8, dim_pad=128)
    m = at.merge(base, pop_block=32)
    assert m.pop_block == 32 and m.dim_pad == 128
    assert at.merge(None, interpret=True) == KernelConfig(interpret=True)


def test_measured_sweep_runs_real_kernel():
    cfg = at.choose("bench_eval", 16, 32, "sphere", interpret=True,
                    measure=True)
    assert at.cache_stats()["measured"] == 1
    assert cfg.pop_block is not None and cfg.pop_block <= 16


def test_kernel_entries_consume_threaded_config():
    """A fully-pinned KernelConfig threads through a kernel entry unchanged
    (the ExecutorConfig.kernel path) and still matches the default config's
    numbers."""
    from repro.kernels.bench_eval import bench_eval
    pop = jax.random.uniform(jax.random.PRNGKey(0), (37, 64),
                             minval=-5.0, maxval=5.0)
    pinned = bench_eval(pop, "rastrigin",
                        kernel_cfg=KernelConfig(pop_block=8, dim_pad=128,
                                                interpret=True))
    auto = bench_eval(pop, "rastrigin")
    assert jnp.max(jnp.abs(pinned - auto) / (jnp.abs(auto) + 1.0)) < 1e-6


# --- roofline smoke (the analyzer the tuner shares constants with) -----------

def test_roofline_analyze_smoke():
    x = jnp.ones((256, 256), jnp.float32)
    compiled = jax.jit(lambda a: a @ a).lower(x).compile()
    r = rl.analyze(compiled)
    assert isinstance(r, rl.Roofline)
    assert r.flops >= 2 * 256**3 * 0.5          # matmul flops dominate
    assert r.t_compute > 0 and r.t_memory > 0
    assert r.bottleneck in ("compute", "memory", "collective")
    d = r.to_dict()
    assert set(d) >= {"flops", "hbm_bytes", "bottleneck"}
