"""Benchmark-function properties (§V testbed), incl. hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep; pip install -r requirements-dev.txt")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.functions import FUNCTIONS, get, make_shifted_rosenbrock

KNOWN_ZERO_AT_ZERO = ["ackley", "rastrigin", "griewank", "sphere", "weierstrass",
                      "lnd1", "lnd2", "lnd6"]


@pytest.mark.parametrize("name", KNOWN_ZERO_AT_ZERO)
def test_optimum_at_origin(name):
    f = get(name)
    v = float(f.fn(jnp.zeros(32)))
    assert abs(v) < 1e-3, (name, v)


def test_rosenbrock_optimum():
    assert abs(float(get("rosenbrock").fn(jnp.ones(64)))) < 1e-5


def test_schwefel_optimum():
    x = jnp.full((50,), 420.9687)
    assert abs(float(get("schwefel").fn(x))) < 0.1


def test_trid_2d_optimum():
    # trid: known optimum f* = -d(d+4)(d-1)/6 at x_i = i(d+1-i)
    d = 6
    x = jnp.array([i * (d + 1 - i) for i in range(1, d + 1)], jnp.float32)
    expected = -d * (d + 4) * (d - 1) / 6
    assert abs(float(get("trid").fn(x)) - expected) < 1e-3


def test_shifted_rosenbrock_bias():
    f = make_shifted_rosenbrock(100)
    from repro.functions import shift_vector
    o = shift_vector(100)
    assert abs(float(f.fn(o)) - 390.0) < 1e-3   # optimum at the shift, f* = 390


@pytest.mark.parametrize("name", sorted(FUNCTIONS))
def test_eval_population_matches_vmap(name):
    f = FUNCTIONS[name]
    pop = jax.random.uniform(jax.random.PRNGKey(0), (7, 12),
                             minval=f.lo, maxval=f.hi)
    batch = f.eval_population(pop)
    single = jnp.stack([f.fn(pop[i]) for i in range(7)])
    np.testing.assert_allclose(batch, single, rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
def test_all_functions_finite_in_domain(dim, seed):
    key = jax.random.PRNGKey(seed)
    for name, f in FUNCTIONS.items():
        x = jax.random.uniform(key, (dim,), minval=f.lo, maxval=f.hi)
        v = f.fn(x)
        assert jnp.isfinite(v), name


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_sphere_shift_invariance(seed):
    """f(x) >= f(0) = 0 and radial monotonicity on rays."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (16,))
    f = FUNCTIONS["sphere"].fn
    assert float(f(x)) >= 0.0
    assert float(f(2.0 * x)) >= float(f(x))
