"""Sharding-rule coherence: specs match parameter trees, all sharded dims
divide on both production meshes, and the roofline HLO parser is exact on a
crafted module. Pure spec-level — no 512-device mesh needed."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, cell_supported, decode_state_specs as dspecs_shapes
from repro.models.transformer import init_params
from repro.optim import adam
from repro.parallel import roofline as rl
from repro.parallel.sharding import (batch_specs, compute_specs,
                                     decode_state_specs, opt_state_specs,
                                     param_specs)

MESHES = {
    "16x16": {"data": 16, "model": 16},
    "2x16x16": {"pod": 2, "data": 16, "model": 16},
}


def _check_divisibility(shapes, specs, sizes, where=""):
    flat_s, td1 = jax.tree_util.tree_flatten(shapes)
    flat_p, td2 = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert td1.num_leaves == td2.num_leaves, f"{where}: tree mismatch"
    for arr, spec in zip(flat_s, flat_p):
        assert len(spec) <= arr.ndim, (where, arr.shape, spec)
        for dim, part in zip(arr.shape, spec):
            if part is None:
                continue
            n = 1
            for ax in (part if isinstance(part, tuple) else (part,)):
                n *= sizes[ax]
            assert dim % n == 0, f"{where}: dim {dim} !% {n} ({spec})"


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_name", sorted(MESHES))
def test_param_specs_divide(arch, mesh_name):
    cfg = get_config(arch)
    sizes = MESHES[mesh_name]
    axes = tuple(sizes)
    pshape = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    _check_divisibility(pshape, param_specs(cfg, axes), sizes, f"{arch} params")
    c = compute_specs(cfg, axes)
    if c is not None:
        _check_divisibility(pshape, c, sizes, f"{arch} compute")
    oshape = jax.eval_shape(adam.init, pshape)
    _check_divisibility(oshape, opt_state_specs(cfg, axes), sizes,
                        f"{arch} opt")


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_cell_specs_divide(arch, shape):
    import dataclasses
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        pytest.skip(why)
    sp = SHAPES[shape]
    cfg = dataclasses.replace(cfg, seq_len=sp.seq_len,
                              global_batch=sp.global_batch)
    for mesh_name, sizes in MESHES.items():
        axes = tuple(sizes)
        if sp.kind == "decode":
            sshape = dspecs_shapes(cfg, sp.global_batch, sp.seq_len)
            _check_divisibility(
                sshape, decode_state_specs(cfg, axes, sp.global_batch),
                sizes, f"{arch}/{shape} state {mesh_name}")


def test_roofline_parser_counts_loops():
    hlo = """HloModule m, is_scheduled=true

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%zero, %a)
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    st = rl.collective_bytes(hlo)
    # all-gather 256*4 once + all-reduce 128*4 * 12 trips
    assert st.by_kind["all-gather"] == 256 * 4
    assert st.by_kind["all-reduce"] == 128 * 4 * 12
    assert st.total_bytes == 256 * 4 + 128 * 4 * 12
