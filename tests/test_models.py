"""Per-arch smoke tests (reduced configs) + model-level consistency.

Every assigned architecture instantiates a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only by launch/dryrun.py (no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data import SyntheticStream
from repro.launch.steps import make_train_step
from repro.models import (ModelConfig, decode_step, forward,
                          init_decode_state, init_params, loss_fn)
from repro.optim import adam

KEY = jax.random.PRNGKey(11)


def _reduced(arch: str) -> ModelConfig:
    return get_config(arch).reduced()


def _batch(cfg: ModelConfig):
    return next(iter(SyntheticStream(cfg)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = _reduced(arch)
    params = init_params(KEY, cfg)
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}

    logits, aux = jax.jit(lambda p: forward(p, cfg, tokens=batch.get("tokens"),
                                            embeds=batch.get("embeds")))(params)
    assert logits.shape == (cfg.global_batch, cfg.seq_len, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    step = jax.jit(make_train_step(cfg, adam.AdamConfig(lr=1e-3)))
    opt = adam.init(params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0.0, arch


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-370m", "zamba2-7b",
                                  "gemma2-9b"])
def test_arch_decode_matches_forward(arch):
    """Teacher-forced decode equals the parallel forward (cache correctness)."""
    cfg = dataclasses.replace(_reduced(arch), remat=False)
    params = init_params(KEY, cfg)
    T = 8
    toks = jax.random.randint(jax.random.fold_in(KEY, 1),
                              (2, T), 0, cfg.vocab)
    full, _ = forward(params, cfg, tokens=toks)
    state = init_decode_state(cfg, 2, T)
    dfn = jax.jit(lambda p, s, t: decode_step(p, cfg, s, tokens=t))
    outs = []
    for t in range(T):
        lg, state = dfn(params, state, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert err / scale < 5e-2, (arch, err, scale)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-370m"])
def test_batched_prefill_matches_per_token_decode(arch):
    """make_prefill_decode (one dispatch) == the per-token decode loop it
    replaced in launch/serve.py: same last logits, same cache position."""
    from repro.launch.steps import make_decode_step, make_prefill_decode
    cfg = dataclasses.replace(_reduced(arch), remat=False)
    params = init_params(KEY, cfg)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (B, T), 0, cfg.vocab)

    step = jax.jit(make_decode_step(cfg))
    st = init_decode_state(cfg, B, T + 4)
    logits = None
    for t in range(T):
        logits, st = step(params, st, {"tokens": toks[:, t:t + 1]})

    prefill = jax.jit(make_prefill_decode(cfg))
    logits2, st2 = prefill(params, init_decode_state(cfg, B, T + 4),
                           {"tokens": toks})

    assert int(st2["pos"]) == int(st["pos"]) == T
    scale = float(jnp.max(jnp.abs(logits))) + 1e-6
    err = float(jnp.max(jnp.abs(logits2 - logits))) / scale
    assert err < 1e-2, (arch, err)
    for name in ("k", "v", "conv", "ssd"):
        if name in st:
            cerr = float(jnp.max(jnp.abs(st2[name].astype(jnp.float32)
                                         - st[name].astype(jnp.float32))))
            assert cerr < 1e-2, (arch, name, cerr)


def test_long_prefill_takes_chunked_cache_path():
    """Prompts past attn_direct_max route through the online-softmax cache
    branch (no (S, T) scores) and match the direct path, including a cache
    length that is not a multiple of the KV block (padding)."""
    from repro.launch.steps import make_prefill_decode
    base = dataclasses.replace(_reduced("llama3.2-1b"), remat=False)
    params = init_params(KEY, base)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 16), 0, base.vocab)
    outs = []
    for cfg in (base, dataclasses.replace(base, attn_direct_max=4,
                                          attn_kv_block=8)):
        st = init_decode_state(cfg, 2, 21)       # 21 % 8 != 0: pads the cache
        lg, st = jax.jit(make_prefill_decode(cfg))(params, st, {"tokens": toks})
        assert int(st["pos"]) == 16
        outs.append(lg)
    err = float(jnp.max(jnp.abs(outs[0] - outs[1])))
    assert err < 1e-3, err


def test_loss_decreases_reduced_llama():
    cfg = _reduced("llama3.2-1b")
    params = init_params(KEY, cfg)
    opt = adam.init(params)
    step = jax.jit(make_train_step(
        cfg, adam.AdamConfig(lr=5e-3, warmup_steps=5, total_steps=100)))
    stream = SyntheticStream(cfg)
    losses = []
    for _ in range(100):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1, losses[::10]


def test_moe_aux_loss_positive():
    cfg = _reduced("qwen2-moe-a2.7b")
    params = init_params(KEY, cfg)
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg).items()}
    _, metrics = loss_fn(params, cfg, batch)
    assert float(metrics["aux"]) > 0.0


def test_vocab_padding_masked():
    cfg = dataclasses.replace(_reduced("llama3.2-1b"), vocab=500, vocab_pad_to=256)
    assert cfg.padded_vocab == 512
    params = init_params(KEY, cfg)
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _ = forward(params, cfg, tokens=toks)
    pad_logits = logits[..., cfg.vocab:]
    assert bool(jnp.all(pad_logits < -1e8))
