"""Multi-job service tests: shape-bucketing, bit-identical parity with
sequential ``IslandOptimizer.minimize``, budget accounting, batching policy
and the JSONL protocol (DESIGN.md §5)."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import (ALGORITHMS, ExecutorConfig, IslandConfig,
                        IslandOptimizer, OptRequest, ShapeBucketScheduler,
                        make_batch_evaluator)
from repro.functions import get
from repro.launch.opt_serve import OptimizationService


def _req(seed=0, **kw):
    base = dict(fn="sphere", algo="de", dim=4, pop=16, n_islands=2,
                sync_every=5, max_evals=1500, migration="ring")
    base.update(kw)
    return OptRequest(seed=seed, **base)


def _sequential(req: OptRequest):
    cfg = IslandConfig(n_islands=req.n_islands, pop=req.pop, dim=req.dim,
                       sync_every=req.sync_every, migration=req.migration,
                       n_migrants=req.n_migrants,
                       share_incumbent=req.share_incumbent,
                       max_evals=req.max_evals)
    opt = IslandOptimizer(ALGORITHMS[req.algo], cfg, params=dict(req.params))
    return opt.minimize(get(req.fn, req.dim), jax.random.PRNGKey(req.seed))


# --- request / bucket-key semantics -----------------------------------------

def test_shape_class_ignores_only_seed():
    assert _req(seed=0).shape_class() == _req(seed=7).shape_class()
    assert _req().shape_class() != _req(dim=5).shape_class()
    assert _req().shape_class() != _req(algo="pso").shape_class()
    assert _req().shape_class() != _req(backend="pallas").shape_class()
    assert _req().shape_class() != _req(params=(("w", 0.9),)).shape_class()


def test_from_dict_normalizes_params_and_rejects_unknown():
    r = OptRequest.from_dict({"fn": "sphere", "params": {"w": 0.7, "px": 0.1}})
    assert r.params == (("px", 0.1), ("w", 0.7))
    # JSON round-trips tuples as lists; the key must stay hashable
    r2 = OptRequest.from_dict({"fn": "sphere", "params": [["w", 0.7]]})
    assert r2.params == (("w", 0.7),)
    hash(r2.shape_class())
    with pytest.raises(ValueError, match="unknown"):
        OptRequest.from_dict({"fn": "sphere", "bogus": 1})


# --- scheduler correctness ---------------------------------------------------

def test_scheduler_bit_identical_to_sequential():
    """K same-shaped requests through the service == K minimize calls."""
    reqs = [_req(seed=s) for s in (0, 3, 0)]
    seq = [_sequential(r) for r in reqs]

    sched = ShapeBucketScheduler()
    ids = [sched.submit(r) for r in reqs]
    sched.flush()
    for jid, expect in zip(ids, seq):
        got = sched.result(jid)
        assert got.status == "done"
        assert got.result.value == expect.value          # bit-identical
        assert got.result.n_evals == expect.n_evals
        assert got.result.n_gens == expect.n_gens
        assert bool(jnp.all(got.result.arg == expect.arg))
    assert sched.n_dispatches == 1                       # one packed run


def test_scheduler_n_evals_budget_accounting():
    """Total evals consumed under the scheduler == same totals sequentially,
    and within each request's budget."""
    reqs = [_req(seed=s, max_evals=2000) for s in range(4)]
    sched = ShapeBucketScheduler()
    ids = [sched.submit(r) for r in reqs]
    sched.flush()
    got = [sched.result(i).result for i in ids]
    seq_total = sum(_sequential(r).n_evals for r in reqs)
    assert sum(r.n_evals for r in got) == seq_total
    assert all(r.n_evals <= 2000 for r in got)


def test_mixed_buckets_route_and_complete():
    reqs = [_req(seed=0), _req(seed=1),                  # bucket A (x2)
            _req(seed=0, dim=6),                         # bucket B
            _req(seed=0, algo="pso", params=())]         # bucket C
    sched = ShapeBucketScheduler()
    ids = [sched.submit(r) for r in reqs]
    assert len(sched.pending_buckets()) == 3
    assert sched.flush() == 4
    assert sched.n_dispatches == 3
    for jid in ids:
        assert sched.result(jid).status == "done"


def test_auto_ids_skip_client_claimed_names():
    sched = ShapeBucketScheduler()
    sched.submit(_req(seed=0), job_id="job0")            # client claims job0
    auto = sched.submit(_req(seed=1))                    # must not collide
    assert auto != "job0"
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(_req(seed=2), job_id="job0")


def test_optimizer_cache_is_lru_capped():
    sched = ShapeBucketScheduler(max_cached_buckets=2)
    for d in (3, 4, 5):
        sched._optimizer(_req(dim=d))
    assert len(sched._optimizers) == 2
    # dim=3 was evicted, dim=5 (MRU) survived
    assert _req(dim=5).shape_class() in sched._optimizers
    assert _req(dim=3).shape_class() not in sched._optimizers


def test_handle_line_rejects_non_object_json():
    from repro.launch.opt_serve import _handle_line
    svc = OptimizationService()
    for payload in ("42", "[1, 2]", '"x"'):
        reply, quit_ = _handle_line(svc, payload)
        assert "error" in reply and not quit_


def test_result_forces_flush_and_poll_does_not():
    sched = ShapeBucketScheduler()
    jid = sched.submit(_req())
    assert sched.poll(jid).status == "queued"
    resp = sched.result(jid)
    assert resp.status == "done" and resp.result is not None


def test_bad_request_errors_are_isolated_per_bucket():
    sched = ShapeBucketScheduler()
    bad = sched.submit(_req(fn="no_such_function"))
    ok = sched.submit(_req())
    sched.flush()
    assert sched.poll(bad).status == "error"
    assert "KeyError" in sched.poll(bad).error
    assert sched.poll(ok).status == "done"


def test_minimize_many_rejects_round_callback():
    cfg = IslandConfig(n_islands=1, pop=8, dim=3, max_evals=500)
    opt = IslandOptimizer(ALGORITHMS["de"], cfg,
                          round_callback=lambda r, a, v: None)
    with pytest.raises(ValueError, match="round_callback"):
        opt.minimize_many(get("sphere"), jnp.stack([jax.random.PRNGKey(0)]))


def test_evaluator_cache_returns_same_callable():
    f = get("sphere")
    cfg = ExecutorConfig(backend="xla")
    assert make_batch_evaluator(f, cfg) is make_batch_evaluator(f, cfg)
    assert make_batch_evaluator(f, cfg) is not make_batch_evaluator(
        f, ExecutorConfig(backend="xla", retry_bad=False))


# --- service layer (queue + deadline flush + protocol) ----------------------

def test_service_max_batch_triggers_dispatch():
    svc = OptimizationService(max_batch=2, flush_ms=1e6)  # deadline disabled
    r1 = svc.handle({"op": "submit", "request":
                     {"fn": "sphere", "dim": 4, "pop": 16, "n_islands": 2,
                      "max_evals": 1500, "seed": 0}})
    assert r1["status"] == "queued"
    r2 = svc.handle({"op": "submit", "request":
                     {"fn": "sphere", "dim": 4, "pop": 16, "n_islands": 2,
                      "max_evals": 1500, "seed": 1}})
    assert r2["status"] == "done"                        # size-based flush
    assert svc.handle({"op": "poll", "id": r1["id"]})["status"] == "done"


def test_service_deadline_flush_via_tick():
    svc = OptimizationService(max_batch=100, flush_ms=0.0)
    r = svc.handle({"op": "submit", "request":
                    {"fn": "sphere", "dim": 4, "pop": 16, "max_evals": 1000}})
    assert svc.handle({"op": "poll", "id": r["id"]})["status"] == "queued"
    assert svc.next_deadline() is not None
    assert svc.tick() == 1                               # deadline passed
    assert svc.handle({"op": "poll", "id": r["id"]})["status"] == "done"
    assert svc.next_deadline() is None


def test_service_protocol_result_and_errors():
    svc = OptimizationService()
    r = svc.handle({"op": "submit", "request":
                    {"fn": "sphere", "dim": 3, "pop": 16, "max_evals": 1000,
                     "seed": 5}})
    out = svc.handle({"op": "result", "id": r["id"]})
    assert out["status"] == "done"
    assert len(out["arg"]) == 3 and out["n_evals"] <= 1000
    json.dumps(out)                                      # JSONL-serializable
    # fetch-once semantics: the record is evicted, the job table stays bounded
    assert "error" in svc.handle({"op": "poll", "id": r["id"]})
    assert len(svc.scheduler._jobs) == 0
    assert "error" in svc.handle({"op": "nope"})
    assert "error" in svc.handle({"op": "submit", "request": {"fn": "sphere",
                                                              "bogus": 1}})
    stats = svc.handle({"op": "stats"})
    assert stats["jobs_run"] == 1 and stats["dispatches"] == 1


def test_stdin_loop_drains_ops_arriving_in_one_write():
    """Ops written in a single chunk must all be answered while the pipe
    stays OPEN (regression: buffered readline stranded trailing ops behind a
    quiet select until EOF)."""
    import os
    import pathlib
    import subprocess
    import sys as _sys
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.Popen(
        [_sys.executable, "-m", "repro.launch.opt_serve"],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
        env=dict(os.environ, PYTHONPATH=src),
    )
    try:
        proc.stdin.write('{"op": "stats"}\n{"op": "stats"}\n{"op": "quit"}\n')
        proc.stdin.flush()                # pipe stays open: no EOF wake-up
        proc.wait(timeout=120)            # quit must terminate the loop
    except subprocess.TimeoutExpired:
        proc.kill()
        pytest.fail("serve_stdin stalled on ops delivered in one write")
    replies = [json.loads(l) for l in proc.stdout.read().splitlines() if l]
    assert len(replies) == 3
    assert replies[-1] == {"bye": True}
