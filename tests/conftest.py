import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests and
# benches must see the real single device; only launch/dryrun.py fakes 512.

# Optional dev-only deps (requirements-dev.txt). Modules that need hypothesis
# guard themselves with ``pytest.importorskip("hypothesis")`` at import time so
# a container without dev requirements sees skips, not collection errors.


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-second fault-injection tests (subprocess SIGKILL harness)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
