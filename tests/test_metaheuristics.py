"""Island-engine + meta-heuristic behaviour tests (the paper's §IV semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
from repro.core import migration
from repro.functions import get

KEY = jax.random.PRNGKey(3)
SPHERE = get("sphere")


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
def test_improves_over_random(algo):
    cfg = IslandConfig(n_islands=2, pop=24, dim=6, sync_every=5,
                       max_evals=6000,
                       migration="starvation" if algo in ("ga", "bh") else "ring")
    res = IslandOptimizer(ALGORITHMS[algo], cfg).minimize(SPHERE, KEY)
    # random uniform in [-100,100]^6 has E[f] = 6 * (200^2/12) = 20000
    assert res.value < 5000, (algo, res.value)
    assert res.n_evals <= cfg.max_evals
    assert np.isfinite(res.value)


def test_budget_respected():
    for budget in (2000, 10_000):
        cfg = IslandConfig(n_islands=1, pop=32, dim=4, migration="none",
                           max_evals=budget)
        res = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
        assert res.n_evals <= budget


def test_de_sync_deterministic():
    cfg = IslandConfig(n_islands=2, pop=16, dim=4, max_evals=4000)
    r1 = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    r2 = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    assert r1.value == r2.value                      # same seed, same result


def test_de_chunked_mode_differs_but_works():
    """The 'non-determinism-ok' flag changes the trajectory (stale reads) but
    is itself reproducible in SPMD."""
    cfg = IslandConfig(n_islands=1, pop=32, dim=6, migration="none",
                       max_evals=8000)
    rs = IslandOptimizer(ALGORITHMS["de"], cfg,
                         params={"barrier_mode": "sync"}).minimize(SPHERE, KEY)
    rc = IslandOptimizer(ALGORITHMS["de"], cfg,
                         params={"barrier_mode": "chunked"}).minimize(SPHERE, KEY)
    rc2 = IslandOptimizer(ALGORITHMS["de"], cfg,
                          params={"barrier_mode": "chunked"}).minimize(SPHERE, KEY)
    assert rc.value == rc2.value
    assert np.isfinite(rs.value) and np.isfinite(rc.value)


def test_best1bin_strategy():
    cfg = IslandConfig(n_islands=1, pop=32, dim=6, migration="none",
                       max_evals=8000)
    r = IslandOptimizer(ALGORITHMS["de"], cfg,
                        params={"strategy": "best1bin"}).minimize(SPHERE, KEY)
    assert r.value < 100.0


# --- migration unit semantics ------------------------------------------------

def test_ring_migration_improves_receiver():
    I, P, D = 4, 8, 3
    pop = jax.random.uniform(KEY, (I, P, D), minval=-1, maxval=1)
    fit = jnp.arange(I * P, dtype=jnp.float32).reshape(I, P)  # island0 best
    new_pop, new_fit = migration.ring(pop, fit, k=2)
    # every island's best fitness can only improve or stay
    assert bool(jnp.all(new_fit.min(axis=1) <= fit.min(axis=1)))
    # island 1 receives island 0's two best
    assert float(new_fit[1].min()) <= float(fit[0].min())
    assert new_pop.shape == pop.shape


def test_ring_migration_conserves_capacity():
    I, P, D = 3, 10, 4
    pop = jax.random.uniform(KEY, (I, P, D))
    fit = jax.random.uniform(jax.random.fold_in(KEY, 1), (I, P))
    new_pop, new_fit = migration.ring(pop, fit, k=2)
    assert new_pop.shape == (I, P, D) and new_fit.shape == (I, P)


def test_starvation_routes_to_weakest():
    I, P, D = 4, 6, 2
    pop = jnp.zeros((I, P, D))
    fit = jnp.full((I, P), 10.0)
    alive = jnp.ones((I, P), bool)
    # island 2 is starving: only 1 live member (others have inf slots)
    fit = fit.at[2, 1:].set(jnp.inf)
    alive = alive.at[2, 1:].set(False)
    fit = fit.at[0, 0].set(1.0)                      # island 0 holds the best
    pop = pop.at[0, 0].set(jnp.array([5.0, 5.0]))
    new_pop, new_fit = migration.starvation(pop, fit, k=2, alive=alive)
    assert float(new_fit[2].min()) == 1.0            # best migrated to host
    assert bool(jnp.all(new_fit[1] == fit[1]))       # non-host islands untouched


def test_ring_adopts_only_better_migrants():
    """A migrant worse than the receiver's resident worst is rejected."""
    I, P, D = 3, 8, 2
    pop = jax.random.uniform(KEY, (I, P, D))
    # island 0's best (the migrants island 1 receives) are all worse than
    # island 1's worst resident -> island 1 must be untouched
    fit = jnp.stack([
        jnp.full((P,), 100.0),                       # donor to island 1
        jnp.arange(P, dtype=jnp.float32),            # receiver, all < 100
        jnp.full((P,), 50.0),
    ])
    new_pop, new_fit = migration.ring(pop, fit, k=2)
    assert bool(jnp.all(new_fit[1] == fit[1]))
    assert bool(jnp.all(new_pop[1] == pop[1]))
    # island 2 (worst resident 50) does adopt island 1's best (0.0)
    assert float(new_fit[2].min()) == 0.0


def test_starvation_picks_emptiest_host():
    """The island with the fewest live members hosts the immigration."""
    I, P, D = 3, 6, 2
    pop = jnp.zeros((I, P, D))
    fit = jnp.full((I, P), 10.0)
    alive = jnp.ones((I, P), bool)
    # live counts: island0 = 6, island1 = 1, island2 = 4  -> host must be 1
    fit = fit.at[1, 1:].set(jnp.inf)
    alive = alive.at[1, 1:].set(False)
    fit = fit.at[2, 4:].set(jnp.inf)
    alive = alive.at[2, 4:].set(False)
    fit = fit.at[0, 0].set(1.0)
    new_pop, new_fit = migration.starvation(pop, fit, k=2, alive=alive)
    assert float(new_fit[1].min()) == 1.0            # arrived at island 1
    assert bool(jnp.all(new_fit[0] == fit[0]))       # donors untouched
    assert bool(jnp.all(new_fit[2] == fit[2]))


def test_starvation_clamps_migrants_to_paper_limit():
    """At most k<=2 individuals leave an island per round, even if k > 2."""
    I, P, D = 3, 8, 2
    pop = jnp.zeros((I, P, D))
    # distinct per-donor fitness bands so arrivals are attributable
    fit = jnp.stack([
        jnp.arange(P, dtype=jnp.float32),            # donor 0: 0..7
        jnp.arange(P, dtype=jnp.float32) + 10.0,     # donor 1: 10..17
        jnp.full((P,), jnp.inf),                     # host: starving (0 alive)
    ])
    new_pop, new_fit = migration.starvation(pop, fit, k=5)
    from_donor0 = int(jnp.sum(new_fit[2] < 10.0))
    from_donor1 = int(jnp.sum((new_fit[2] >= 10.0) & (new_fit[2] < 20.0)))
    assert from_donor0 <= 2 and from_donor1 <= 2, (from_donor0, from_donor1)
    assert from_donor0 == 2                          # the best two did arrive
    assert float(new_fit[2].min()) == 0.0


def test_no_migration_single_island():
    pop = jax.random.uniform(KEY, (1, 8, 3))
    fit = jax.random.uniform(jax.random.fold_in(KEY, 2), (1, 8))
    p2, f2 = migration.ring(pop, fit, 2)
    assert bool(jnp.all(p2 == pop)) and bool(jnp.all(f2 == fit))


def test_incumbent_sharing():
    cfg = IslandConfig(n_islands=4, pop=16, dim=4, sync_every=5,
                       max_evals=4000, share_incumbent=True)
    res = IslandOptimizer(ALGORITHMS["pso"], cfg).minimize(SPHERE, KEY)
    assert np.isfinite(res.value)


def test_history_monotone():
    cfg = IslandConfig(n_islands=2, pop=16, dim=4, max_evals=6000)
    res = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(SPHERE, KEY)
    hist = res.history
    assert all(hist[i + 1] <= hist[i] + 1e-9 for i in range(len(hist) - 1))


# --- eval accounting parity (all eight registered policies) ------------------

# Non-default pop (!= the paper's P=50 FA default, not divisible by chunked
# DE's n_chunks) so shape-dependent accounting bugs cannot hide.
PARITY_CASES = [(name, {}) for name in sorted(ALGORITHMS)] + [
    ("de", {"barrier_mode": "chunked", "n_chunks": 8}),
]


@pytest.mark.parametrize("name,params", PARITY_CASES,
                         ids=[n + ("-chunked" if p else "") for n, p in PARITY_CASES])
def test_evals_per_gen_parity(name, params):
    """Charged accounting == actual evaluator rows, per init and per
    generation, for every registered policy: fa's O(P^2) pairwise attraction
    must stay eval-free (exactly pop rows per gen at any pop), and chunked
    DE must charge its clamped-slice overlap (csz * n_chunks rows, not pop).
    """
    from repro.functions import get
    pop, dim = 37, 5
    f = get("sphere", dim)
    counted: list[int] = []

    def counting_evaluator(p):
        n = p.shape[0]                       # static: rows per evaluator call
        jax.debug.callback(lambda: counted.append(n))
        return jnp.sum(p * p, axis=-1)

    algo = ALGORITHMS[name](f=f, evaluator=counting_evaluator,
                            pop=pop, dim=dim, **params)
    barrier = getattr(jax, "effects_barrier", lambda: None)

    state = jax.block_until_ready(algo.init(jax.random.PRNGKey(0)))
    barrier()
    assert sum(counted) == algo.init_evals, (name, counted)

    counted.clear()
    jax.block_until_ready(algo.gen(state, jax.random.PRNGKey(1)))
    barrier()
    assert sum(counted) == algo.evals_per_gen, (name, counted)
