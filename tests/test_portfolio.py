"""Heterogeneous algorithm-portfolio island tests (DESIGN.md §10).

Three tiers:

* Determinism contract: a fixed-seed HOMOGENEOUS portfolio (every island
  ``algo_id=de``) is bit-identical to the plain ``algo_maker``-driven engine
  across ``minimize``, ``minimize_many`` and sharded runs (the 8-device case
  runs under CI's distributed-smoke job). Mixed portfolios are bit-
  reproducible for a fixed device layout; across layouts they are value-
  stable only (XLA may fuse the ``lax.switch`` branches differently per
  batch size and reassociate the evaluator's reductions).
* Cross-algorithm migration semantics: migrants carry pos/fit only; the
  destination policy re-initializes its aux slots on adoption (PSO velocity
  zeroed, pbest restarted at the migrant; GA age reset, ``alive`` revived).
* Stack plumbing: shape-class separation, scheduler bucket parity, JSONL
  service round trip, and the registry's schema invariants.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, MeshConfig,
                        OptRequest, ShapeBucketScheduler)
from repro.core import portfolio as pf
from repro.functions import get

KEY = jax.random.PRNGKey(11)
N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _cfg(**kw):
    base = dict(n_islands=4, pop=16, dim=6, sync_every=5, migration="ring",
                max_evals=6000)
    base.update(kw)
    return IslandConfig(**base)


def _assert_same(a, b):
    assert a.value == b.value
    assert a.n_evals == b.n_evals and a.n_gens == b.n_gens
    assert np.array_equal(np.asarray(a.arg), np.asarray(b.arg))
    assert np.array_equal(np.asarray(a.history), np.asarray(b.history))


# --- registry / schema -------------------------------------------------------

def test_registry_covers_all_engine_algorithms():
    """Every ALGORITHMS entry is registered with a unique, stable algo_id."""
    assert set(pf.REGISTRY) == set(ALGORITHMS)
    ids = [s.algo_id for s in pf.REGISTRY.values()]
    assert len(ids) == len(set(ids))
    # frozen wire ids — renumbering breaks serialized requests
    assert pf.REGISTRY["de"].algo_id == 0
    assert pf.REGISTRY["ga"].algo_id == 1
    assert pf.REGISTRY["pso"].algo_id == 2


def test_schema_is_registry_wide_maximum():
    nv, np_, ns = pf.schema()
    assert nv >= 2 and np_ >= 2 and ns >= 1   # pso: 2 vec; ga: 2 ind; sa: 1 scl


def test_unified_state_shares_one_pytree_structure():
    """Every policy's unified init produces the same pytree structure — the
    precondition for lax.switch branches."""
    f = get("sphere", 4)
    ev = f.eval_population
    structs = set()
    for name, spec in pf.REGISTRY.items():
        algo = spec.maker(f=f, evaluator=ev, pop=8, dim=4)
        u = pf.UnifiedPolicy(spec, algo, 8, 4).init(KEY)
        structs.add(jax.tree.structure(u)
                    if hasattr(jax.tree, "structure")
                    else jax.tree_util.tree_structure(u))
        assert u["alive"].dtype == jnp.bool_ and u["alive"].shape == (8,)
    assert len(structs) == 1


def test_expand_cycles_and_validates():
    assert pf.expand(("de", "pso"), 5) == ("de", "pso", "de", "pso", "de")
    assert pf.expand(("de", "pso", "sa"), 3) == ("de", "pso", "sa")
    with pytest.raises(ValueError, match="unknown"):
        pf.expand(("nope",), 2)
    with pytest.raises(ValueError, match="empty"):
        pf.expand((), 2)
    # over-length specs are rejected, never silently truncated
    with pytest.raises(ValueError, match="only 2 islands"):
        pf.expand(("de", "pso", "sa"), 2)


def test_build_portfolio_rejects_params_for_absent_policies():
    f = get("sphere", 4)
    with pytest.raises(ValueError, match="not in the portfolio"):
        pf.build_portfolio(("de", "pso"), f, f.eval_population, 8, 4,
                           params={"sa": {"T0": 1.0}})


# --- determinism contract ----------------------------------------------------

@pytest.mark.parametrize("algo", ["de", "pso", "sa", "bh"])
def test_homogeneous_portfolio_bit_identical_minimize(algo):
    """The contract holds for every policy, not just de: the plain engine
    applies the same registered adopt rules (adopt_native), so a homogeneous
    portfolio and the algo_maker engine share one trajectory."""
    f = get("rastrigin", 6)
    plain = IslandOptimizer(ALGORITHMS[algo], _cfg()).minimize(f, KEY)
    port = IslandOptimizer(None, _cfg(portfolio=(algo,))).minimize(f, KEY)
    _assert_same(plain, port)


def test_homogeneous_de_portfolio_bit_identical_minimize_many():
    f = get("sphere", 6)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 3, 11)])
    plain = IslandOptimizer(ALGORITHMS["de"], _cfg()).minimize_many(f, keys)
    port = IslandOptimizer(None, _cfg(portfolio=("de",))).minimize_many(f, keys)
    for a, b in zip(plain, port):
        _assert_same(a, b)


def test_homogeneous_de_portfolio_bit_identical_one_device_mesh():
    f = get("rastrigin", 6)
    plain = IslandOptimizer(ALGORITHMS["de"], _cfg()).minimize(f, KEY)
    port = IslandOptimizer(None, _cfg(portfolio=("de",)),
                           mesh_cfg=MeshConfig(devices=1)).minimize(f, KEY)
    _assert_same(plain, port)


@needs8
def test_homogeneous_de_portfolio_bit_identical_eight_devices():
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=8, max_evals=8000)
    plain = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, KEY)
    port = IslandOptimizer(None, dataclasses.replace(cfg, portfolio=("de",)),
                           mesh_cfg=MeshConfig(devices=8)).minimize(f, KEY)
    _assert_same(plain, port)


@needs8
def test_homogeneous_de_portfolio_bit_identical_eight_devices_many():
    f = get("levy", 6)
    cfg = _cfg(n_islands=8, max_evals=6000)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 4)])
    plain = IslandOptimizer(ALGORITHMS["de"], cfg).minimize_many(f, keys)
    port = IslandOptimizer(None, dataclasses.replace(cfg, portfolio=("de",)),
                           mesh_cfg=MeshConfig(devices=8)).minimize_many(f, keys)
    for a, b in zip(plain, port):
        _assert_same(a, b)


def test_mixed_portfolio_deterministic_and_improves():
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=6, max_evals=9000, portfolio=("de", "pso", "sa"))
    params = {"sa": {"T0": 50.0}}
    r1 = IslandOptimizer(None, cfg, params=params).minimize(f, KEY)
    r2 = IslandOptimizer(None, cfg, params=params).minimize(f, KEY)
    _assert_same(r1, r2)
    assert r1.value < 50.0 and np.isfinite(r1.value)
    assert r1.n_evals <= cfg.max_evals
    hist = np.asarray(r1.history)
    assert np.all(np.diff(hist) <= 0)          # incumbent is monotone


def test_mixed_portfolio_minimize_many_matches_minimize():
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=6, max_evals=9000, portfolio=("de", "pso", "sa"))
    seeds = (0, 5)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    many = IslandOptimizer(None, cfg).minimize_many(f, keys)
    for s, got in zip(seeds, many):
        solo = IslandOptimizer(None, cfg).minimize(f, jax.random.PRNGKey(s))
        _assert_same(solo, got)


def test_mixed_portfolio_one_device_mesh_bit_identical():
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=6, max_evals=9000, portfolio=("de", "pso", "sa"))
    u = IslandOptimizer(None, cfg).minimize(f, KEY)
    s = IslandOptimizer(None, cfg, mesh_cfg=MeshConfig(devices=1)).minimize(f, KEY)
    _assert_same(u, s)


@needs8
def test_mixed_portfolio_eight_devices_value_stable():
    """Across device layouts mixed portfolios are value-stable, not bit-
    identical: XLA fuses the switch branches per batch size and may
    reassociate the evaluator's reductions (DESIGN.md §10)."""
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=8, max_evals=12000,
               portfolio=("de", "pso", "sa", "ea"))
    u = IslandOptimizer(None, cfg).minimize(f, KEY)
    s = IslandOptimizer(None, cfg, mesh_cfg=MeshConfig(devices=8)).minimize(f, KEY)
    s2 = IslandOptimizer(None, cfg, mesh_cfg=MeshConfig(devices=8)).minimize(f, KEY)
    _assert_same(s, s2)                        # fixed layout: bit-reproducible
    np.testing.assert_allclose(np.asarray(u.history), np.asarray(s.history),
                               rtol=1e-5)
    assert u.n_evals == s.n_evals and u.n_gens == s.n_gens


def test_portfolio_composes_with_polish_and_incumbent_sharing():
    f = get("rosenbrock", 6)
    cfg = _cfg(n_islands=4, max_evals=8000, portfolio=("de", "pso"),
               share_incumbent=True, polish="asd", polish_every=2,
               polish_topk=2, polish_steps=2)
    r1 = IslandOptimizer(None, cfg).minimize(f, KEY)
    r2 = IslandOptimizer(None, cfg).minimize(f, KEY)
    _assert_same(r1, r2)
    assert r1.n_evals <= cfg.max_evals


def test_portfolio_heterogeneous_budget_accounting():
    """Islands charge their OWN policy's evals_per_gen: a ga island (n_off
    per gen) costs less than a de island (pop per gen), and the round total
    is the per-island sum."""
    f = get("sphere", 4)
    cfg = _cfg(n_islands=2, pop=16, dim=4, migration="none",
               portfolio=("de", "ga"), max_evals=2000)
    opt = IslandOptimizer(None, cfg)
    port = opt._build(f)
    n_off = max(1, 16 // 4)
    assert port.per_gen_total == 16 + n_off
    assert port.init_total == 32
    res = opt.minimize(f, KEY)
    assert res.n_evals <= cfg.max_evals
    rounds = res.n_gens // cfg.sync_every
    assert res.n_evals == 32 + rounds * cfg.sync_every * (16 + n_off)


def test_portfolio_mode_validation():
    with pytest.raises(ValueError, match="algo_maker=None"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(portfolio=("de", "pso")))
    with pytest.raises(ValueError, match="n_islands > 1"):
        IslandOptimizer(None, _cfg(n_islands=1, migration="none",
                                   portfolio=("de",)))
    with pytest.raises(ValueError, match="algo_maker is required"):
        IslandOptimizer(None, _cfg())


# --- cross-algorithm migration semantics ------------------------------------

def _unified(name, f, pop=6, dim=3, **kw):
    spec = pf.REGISTRY[name]
    algo = spec.maker(f=f, evaluator=f.eval_population, pop=pop, dim=dim, **kw)
    return pf.UnifiedPolicy(spec, algo, pop, dim)


def test_adopt_reinitializes_pso_aux_slots():
    f = get("sphere", 3)
    up = _unified("pso", f)
    u = up.init(KEY)
    # pretend slots 1 and 4 adopted migrants: pop/fit already overwritten
    mask = jnp.asarray([False, True, False, False, True, False])
    mig_pos = jnp.full((3,), 7.0)
    u = {**u, "pop": u["pop"].at[1].set(mig_pos).at[4].set(-mig_pos),
         "fit": u["fit"].at[1].set(0.5).at[4].set(0.25)}
    v = up.adopt(u, mask)
    vel, pbest = v["aux_vec"][0], v["aux_vec"][1]
    pbest_f = v["aux_ind"][0]
    assert np.all(np.asarray(vel[1]) == 0) and np.all(np.asarray(vel[4]) == 0)
    assert np.array_equal(np.asarray(pbest[1]), np.asarray(v["pop"][1]))
    assert np.array_equal(np.asarray(pbest[4]), np.asarray(v["pop"][4]))
    assert pbest_f[1] == 0.5 and pbest_f[4] == 0.25
    # untouched rows keep their aux state
    assert np.array_equal(np.asarray(vel[0]), np.asarray(u["aux_vec"][0][0]))
    assert np.array_equal(np.asarray(pbest[2]), np.asarray(u["aux_vec"][1][2]))
    assert np.all(np.asarray(v["alive"]))


def test_adopt_revives_and_rejuvenates_ga_slots():
    f = get("sphere", 3)
    up = _unified("ga", f, age_mean=10.0, age_sd=0.0)
    u = up.init(KEY)
    # age everyone, kill slot 2, then adopt a migrant into it
    u = {**u, "aux_ind": u["aux_ind"].at[0].set(9.0),
         "alive": u["alive"].at[2].set(False)}
    mask = jnp.asarray([False, False, True, False, False, False])
    v = up.adopt(u, mask)
    age, limit = v["aux_ind"][0], v["aux_ind"][1]
    assert age[2] == 0.0                        # migrant arrives newborn
    assert age[0] == 9.0                        # non-adopted ages untouched
    assert limit[2] == u["aux_ind"][1][2]       # slot keeps its drawn limit
    assert bool(v["alive"][2])                  # revived
    assert not bool(u["alive"][2])


def test_adopt_keeps_per_island_scalars():
    f = get("sphere", 3)
    for name in ("sa", "ea", "fa"):
        up = _unified(name, f)
        u = up.init(KEY)
        u = {**u, "aux_scl": u["aux_scl"].at[0].set(3.25)}
        v = up.adopt(u, jnp.ones((6,), bool))
        assert v["aux_scl"][0] == 3.25


def test_ring_migration_across_policies_adopts_only_better():
    """2-island (de -> pso) ring: the pso island adopts de's best only when
    it beats its own worst, and the adopted slot's velocity re-initializes
    inside the jitted engine run."""
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=2, pop=12, max_evals=4000, sync_every=3,
               n_migrants=2, portfolio=("de", "pso"))
    r1 = IslandOptimizer(None, cfg).minimize(f, KEY)
    r2 = IslandOptimizer(None, cfg).minimize(f, KEY)
    _assert_same(r1, r2)
    assert np.isfinite(r1.value)
    hist = np.asarray(r1.history)
    assert np.all(np.diff(hist) <= 0)


def test_starvation_migration_into_aging_ga_island():
    """ga islands age out; starvation re-seeds them from the other policies'
    best, and the adopted slots come back alive (the engine-level aux
    re-init path)."""
    f = get("rastrigin", 6)
    cfg = _cfg(n_islands=4, pop=12, max_evals=8000, migration="starvation",
               portfolio=("ga", "pso", "ga", "sa"))
    params = {"ga": {"age_mean": 6.0, "age_sd": 1.0}, "sa": {"T0": 20.0}}
    r1 = IslandOptimizer(None, cfg, params=params).minimize(f, KEY)
    r2 = IslandOptimizer(None, cfg, params=params).minimize(f, KEY)
    _assert_same(r1, r2)
    assert np.isfinite(r1.value) and r1.value < 100.0


def test_plain_ga_starvation_revives_adopted_slots():
    """The engine-level fix the portfolio layer generalizes: in plain mode a
    ga island's adopted migrants revive AND their age resets — else the next
    generation's age > age_limit check re-kills the migrant the slot just
    adopted. Enforced by bit-identity with the homogeneous ga portfolio,
    whose adopt rule (age zero, limit keep, alive revive) is the same."""
    f = get("rastrigin", 6)
    for mig in ("starvation", "ring"):
        cfg = _cfg(n_islands=4, pop=12, max_evals=8000, migration=mig)
        params = {"age_mean": 6.0, "age_sd": 1.0}
        plain = IslandOptimizer(ALGORITHMS["ga"], cfg,
                                params=params).minimize(f, KEY)
        port = IslandOptimizer(
            None, dataclasses.replace(cfg, portfolio=("ga",)),
            params={"ga": params}).minimize(f, KEY)
        _assert_same(plain, port)
        assert np.isfinite(plain.value)


def test_homogeneous_portfolio_starvation_matches_plain_under_eviction():
    """Starvation counts live slots as isfinite(fit) for policies that do not
    own an alive mask; the portfolio's all-True common mask must not change
    that. An objective that fails on half the domain (executor evicts to
    +inf) makes the starvation trigger depend on it — plain and homogeneous
    portfolio must still agree bit-for-bit."""
    from repro.functions.benchmarks import Function

    def half_bad(x):
        s = jnp.sum(x * x, axis=-1)
        return jnp.where(x[..., 0] > 0.0, jnp.nan, s)

    f = Function("half_bad_sphere", half_bad, -10.0, 10.0)
    cfg = _cfg(n_islands=4, pop=12, max_evals=5000, migration="starvation")
    plain = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, KEY)
    port = IslandOptimizer(None, dataclasses.replace(cfg, portfolio=("de",))
                           ).minimize(f, KEY)
    _assert_same(plain, port)
    assert np.isfinite(plain.value)


# --- stack plumbing ----------------------------------------------------------

def test_portfolio_joins_shape_class():
    base = dict(fn="sphere", n_islands=4)
    a = OptRequest(**base)
    b = OptRequest(portfolio=("de", "pso"), **base)
    c = OptRequest(portfolio=("de", "sa"), **base)
    assert len({a.shape_class(), b.shape_class(), c.shape_class()}) == 3
    assert (OptRequest(portfolio=("de", "pso"), seed=0, **base).shape_class()
            == OptRequest(portfolio=("de", "pso"), seed=7, **base).shape_class())
    # algo is ignored in portfolio mode and normalized out of the bucket key,
    # so habitually-set algo values cannot split identical portfolio jobs
    assert (OptRequest(portfolio=("de", "pso"), algo="de", **base).shape_class()
            == OptRequest(portfolio=("de", "pso"), algo="ga", **base).shape_class())


def test_from_dict_freezes_portfolio_and_nested_params():
    req = OptRequest.from_dict({
        "fn": "rastrigin", "n_islands": 6, "portfolio": ["de", "pso", "sa"],
        "params": {"sa": {"T0": 50.0}, "de": {"w": 0.7}}})
    assert req.portfolio == ("de", "pso", "sa")
    assert isinstance(req.params, tuple)
    hash(req.shape_class())                    # must stay hashable
    assert dict(req.params)["sa"] == (("T0", 50.0),)


def test_scheduler_portfolio_bucket_matches_standalone():
    base = {"fn": "rastrigin", "dim": 6, "pop": 16, "n_islands": 6,
            "sync_every": 5, "max_evals": 6000,
            "portfolio": ["de", "pso", "sa"], "params": {"sa": {"T0": 50.0}}}
    sched = ShapeBucketScheduler()
    ids = [sched.submit(OptRequest.from_dict({**base, "seed": s}))
           for s in (0, 4)]
    plain_id = sched.submit(OptRequest(fn="rastrigin", dim=6, pop=16,
                                       n_islands=6, sync_every=5,
                                       max_evals=6000, seed=0))
    assert len(sched.pending_buckets()) == 2   # portfolio and plain split
    assert sched.flush() == 3
    cfg = _cfg(n_islands=6, portfolio=("de", "pso", "sa"))
    f = get("rastrigin", 6)
    for jid, seed in zip(ids, (0, 4)):
        got = sched.result(jid)
        assert got.status == "done"
        expect = IslandOptimizer(None, cfg, params={"sa": {"T0": 50.0}}
                                 ).minimize(f, jax.random.PRNGKey(seed))
        assert got.result.value == expect.value
        assert np.array_equal(np.asarray(got.result.arg),
                              np.asarray(expect.arg))
    assert sched.result(plain_id).status == "done"


def test_opt_serve_portfolio_round_trip():
    from repro.launch.opt_serve import OptimizationService
    svc = OptimizationService(max_batch=8, flush_ms=5.0)
    out = svc.handle({"op": "submit", "request": {
        "fn": "sphere", "dim": 4, "pop": 16, "n_islands": 4,
        "portfolio": ["de", "pso"], "max_evals": 3000, "seed": 0}})
    assert out["status"] == "queued"
    res = svc.handle({"op": "result", "id": out["id"]})
    assert res["status"] == "done" and np.isfinite(res["value"])
    assert len(res["arg"]) == 4
