"""Data pipeline: determinism, cursor restart, modality stubs, label masking."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticStream


def test_deterministic_per_step():
    cfg = get_config("llama3.2-1b").reduced()
    a = SyntheticStream(cfg)
    b = SyntheticStream(cfg)
    for _ in range(3):
        ba, bb = next(a), next(b)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])


def test_cursor_restart_resumes_stream():
    cfg = get_config("llama3.2-1b").reduced()
    a = SyntheticStream(cfg)
    batches = [next(a) for _ in range(5)]
    st = a.state_dict()
    b = SyntheticStream(cfg)
    for _ in range(5):
        next(b)
    bb = SyntheticStream(cfg)
    bb.load_state_dict(st)
    nxt_a, nxt_b = next(a), next(bb)
    for k in nxt_a:
        np.testing.assert_array_equal(nxt_a[k], nxt_b[k])


def test_seed_mismatch_rejected():
    cfg = get_config("llama3.2-1b").reduced()
    s = SyntheticStream(cfg, DataConfig(seed=17))
    with pytest.raises(AssertionError):
        s.load_state_dict({"step": 0, "seed": 23})


def test_vlm_batch_shapes_and_masking():
    cfg = get_config("internvl2-2b").reduced()
    b = next(iter(SyntheticStream(cfg)))
    assert b["embeds"].shape == (cfg.global_batch, cfg.frontend_len,
                                 cfg.frontend_dim)
    assert b["tokens"].shape == (cfg.global_batch,
                                 cfg.seq_len - cfg.frontend_len)
    assert b["labels"].shape == (cfg.global_batch, cfg.seq_len)
    assert (b["labels"][:, :cfg.frontend_len] == -100).all()  # image prefix


def test_audio_batch_shapes():
    cfg = get_config("musicgen-medium").reduced()
    b = next(iter(SyntheticStream(cfg)))
    assert b["embeds"].shape == (cfg.global_batch, cfg.seq_len,
                                 cfg.frontend_dim)
    assert b["labels"].max() < cfg.vocab
