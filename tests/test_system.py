"""End-to-end behaviour of the paper's system (§III/§V semantics)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, ExecutorConfig, IslandConfig, IslandOptimizer
from repro.core.api import ObserverHub
from repro.core.executor import make_batch_evaluator
from repro.functions import Function, get, make_shifted_rosenbrock

KEY = jax.random.PRNGKey(0)


def test_executor_retry_semantics():
    """A 'failing worker' (NaN result) is retried once, then evicted (+inf) —
    the paper's resubmit-once policy."""
    def bad(x):
        return jnp.where(x[0] > 0, jnp.nan, jnp.sum(x * x))

    f = Function("bad", bad, -1.0, 1.0)
    ev = make_batch_evaluator(f, ExecutorConfig(retry_bad=True))
    pop = jnp.array([[0.5, 0.0], [-0.5, 0.0]])
    fit = ev(pop)
    assert np.isposinf(float(fit[0]))       # evicted after retry
    assert np.isfinite(float(fit[1]))


def test_executor_equal_chunking_shape():
    f = get("sphere")
    ev = make_batch_evaluator(f, ExecutorConfig())
    pop = jax.random.uniform(KEY, (13, 5))
    assert ev(pop).shape == (13,)


def test_observer_hub_refinement():
    hub = ObserverHub()
    calls = []

    def refine(arg, val):
        calls.append(float(val))
        return arg * 0.5, val / 2.0

    hub.register(refine)
    arg, val = hub.notify(jnp.ones(3), 8.0)
    assert val == 4.0 and len(calls) == 1
    arg, val = hub.notify(jnp.ones(3), 9.0)   # worse incumbent -> no refine
    assert val == 4.0 and len(calls) == 1


def test_shifted_rosenbrock_de_sanity():
    """Scaled-down §V.A: single-island DDE on shifted Rosenbrock. The full run
    (1000-D, pop 800, 20k gens) lives in examples/distributed_de.py."""
    f = make_shifted_rosenbrock(16)
    cfg = IslandConfig(n_islands=1, pop=64, dim=16, migration="none",
                       max_evals=30_000)
    res = IslandOptimizer(ALGORITHMS["de"], cfg,
                          params={"w": 0.5, "px": 0.2,
                                  "barrier_mode": "chunked"}).minimize(f, KEY)
    # optimum is 390; random init is >1e9
    assert res.value < 1e6
    assert res.value >= 390.0 - 1e-3


def test_dryrun_sets_flags_first():
    """dryrun must set XLA flags before importing jax."""
    src = open("src/repro/launch/dryrun.py").read()
    first = [ln for ln in src.splitlines() if ln and not ln.startswith("#")][:2]
    assert first[0].startswith("import os")
    assert "XLA_FLAGS" in first[1]
