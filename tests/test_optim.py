"""Gradient-based optimizer tests (popt4jlib.GradientDescent + Adam).

Only the Hypothesis property test is gated on the dev-only ``hypothesis``
dependency; the convergence/accounting tests below run everywhere (they used
to be skipped wholesale behind a module-level importorskip)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.functions import get
from repro.optim import DescentConfig, adam, asd, avd, bfgs, fcg
from repro.optim.numgrad import make_grad, richardson_grad

KEY = jax.random.PRNGKey(5)
SPHERE = get("sphere")

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:       # dev-only dep; pip install -r requirements-dev.txt
    given = None


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 12), st.integers(0, 2 ** 31 - 1))
    def test_richardson_matches_autodiff(dim, seed):
        f = SPHERE.fn
        x = jax.random.uniform(jax.random.PRNGKey(seed), (dim,),
                               minval=-5.0, maxval=5.0)
        g_num, n = richardson_grad(f, x, h=1e-2)  # h sized for f32 cancellation
        g_ad = jax.grad(f)(x)
        assert n == 4 * dim
        np.testing.assert_allclose(g_num, g_ad, rtol=5e-3, atol=5e-3)
else:
    @pytest.mark.skip(reason="hypothesis not installed; "
                             "pip install -r requirements-dev.txt")
    def test_richardson_matches_autodiff():
        pass


def test_richardson_eval_accounting():
    grad_fn = make_grad(SPHERE.fn, "richardson")
    _, n = grad_fn(jnp.zeros(7))
    assert n == 28
    grad_fn = make_grad(SPHERE.fn, "autodiff")
    _, n = grad_fn(jnp.zeros(7))
    assert n == 2


@pytest.mark.parametrize("method,tol", [(asd, 1e-4), (fcg, 1e-4),
                                        (bfgs, 1e-4), (avd, 1.0)])
def test_descent_sphere(method, tol):
    cfg = DescentConfig(max_evals=15_000)
    res = method(SPHERE, KEY, 8, cfg)
    assert res.value < tol
    # budget check: an in-flight iteration may finish (AVD: one full sweep
    # = dim * 2 * (2*expansions+1) evals; others: one gradient + line search)
    assert res.n_evals <= cfg.max_evals + 8 * 2 * 17 + 50


def test_fcg_rosenbrock_progress():
    f = get("rosenbrock")
    res = fcg(f, KEY, 8, DescentConfig(max_evals=30_000))
    assert res.value < 1e4  # random point is ~1e9


@pytest.mark.parametrize("method,tol", [(asd, 1e4), (fcg, 1e4),
                                        (bfgs, 1e4), (avd, 1e5)])
def test_descent_rosenbrock_progress(method, tol):
    """All four LocalOptimizerIntf methods make real progress down the
    Rosenbrock valley (a random point in the box is ~1e9; AVD's axis-aligned
    probes track the curved valley slowest)."""
    f = get("rosenbrock")
    res = method(f, KEY, 6, DescentConfig(max_evals=20_000))
    assert res.value < tol
    assert res.n_evals <= 20_000 + 6 * 2 * 17 + 50


def test_avd_quantized():
    cfg = DescentConfig(max_evals=5_000, avd_quantum=0.5)
    res = avd(SPHERE, KEY, 4, cfg)
    # every coordinate is a multiple of the quantum
    q = np.asarray(res.arg) / 0.5
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)


def test_adam_minimize():
    res = adam.adam_minimize(SPHERE, KEY, 8, max_evals=30_000, lr=1.0)
    assert res.value < 10.0


def test_adam_pytree_matches_reference():
    """One Adam step against the closed-form update."""
    cfg = adam.AdamConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                          grad_clip=0.0, warmup_steps=1, total_steps=10,
                          min_lr_frac=1.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, -0.2, 0.3])}
    st_ = adam.init(params)
    new, st2 = adam.update(grads, st_, params, cfg)
    g = np.array([0.1, -0.2, 0.3])
    m = 0.1 * g
    v = 0.001 * g * g
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    expect = np.array([1.0, -2.0, 3.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(new["w"], expect, rtol=1e-5)
    assert int(st2.step) == 1


def test_adam_grad_clip():
    cfg = adam.AdamConfig(lr=0.1, grad_clip=1.0, warmup_steps=1,
                          total_steps=10, min_lr_frac=1.0)
    params = {"w": jnp.zeros(3)}
    huge = {"w": jnp.array([1e6, 0.0, 0.0])}
    st_ = adam.init(params)
    new, _ = adam.update(huge, st_, params, cfg)
    assert float(jnp.max(jnp.abs(new["w"]))) < 1.0  # clipped step is bounded


def test_ga_fcg_combo_budget():
    from repro.core import ALGORITHMS, IslandConfig, IslandOptimizer
    from repro.core.coupling import with_fcg_postprocessing
    meta = IslandOptimizer(ALGORITHMS["ga"],
                           IslandConfig(n_islands=1, pop=16, dim=6,
                                        migration="none"))
    res = with_fcg_postprocessing(meta, SPHERE, KEY, 6, total_evals=10_000)
    assert res.value < 100.0
    assert res.n_evals <= 11_000
