"""Async staleness-bounded island tests (DESIGN.md §13, ISSUE 8 tentpole).

The two contracts the harness locks down:

* **Degradation**: ``sync_policy="async"`` with ``max_staleness=0`` under the
  default all-ones schedule is **bit-identical** to the barrier engine — for
  ``minimize``, ``minimize_many`` and the 1-device mesh, across de/pso/ga/sa.
  (The async round body applies its step mask *outside* the generation scan
  precisely so the inner scan stays HLO-identical to the barrier's.)
* **Record/replay**: an async run under any schedule records the exact
  step/deliver masks it used (``IslandOptimizer.recorded_schedule``); feeding
  them back reproduces the run bit-identically, and every adopted migrant's
  staleness stays ≤ ``max_staleness`` (``last_max_staleness``).

Plus the mailbox edge cases the async path exposes: ring-full overwrite,
too-stale migrants dropped, and the n_islands=1 self-loop no-op.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, AsyncSchedule, IslandConfig, IslandOptimizer
from repro.core import migration as mig
from repro.core.mesh import MeshConfig
from repro.functions import get

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:       # dev-only dep; pip install -r requirements-dev.txt
    given = None

KEY = jax.random.PRNGKey(7)
F6 = get("rastrigin", 6)
ALGOS = ["de", "pso", "ga", "sa"]


def _cfg(**kw):
    base = dict(n_islands=4, pop=16, dim=6, sync_every=3, migration="ring",
                n_migrants=2, max_evals=3000)
    base.update(kw)
    return IslandConfig(**base)


def _same(a, b):
    return (a.value == b.value
            and np.array_equal(np.asarray(a.arg), np.asarray(b.arg))
            and np.array_equal(np.asarray(a.history), np.asarray(b.history)))


# --- degradation: max_staleness=0 ≡ barrier ---------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_async_staleness0_bit_identical_to_barrier(algo):
    cb = _cfg()
    ca = dataclasses.replace(cb, sync_policy="async", max_staleness=0)
    rb = IslandOptimizer(ALGORITHMS[algo], cb).minimize(F6, KEY)
    oa = IslandOptimizer(ALGORITHMS[algo], ca)
    ra = oa.minimize(F6, KEY)
    assert _same(rb, ra)
    # uniform cadence: every adoption is exactly 0 rounds stale
    assert oa.last_max_staleness == 0


def test_async_staleness0_minimize_many_bit_identical():
    cb = _cfg()
    ca = dataclasses.replace(cb, sync_policy="async", max_staleness=0)
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    mb = IslandOptimizer(ALGORITHMS["de"], cb).minimize_many(F6, keys)
    ma = IslandOptimizer(ALGORITHMS["de"], ca).minimize_many(F6, keys)
    for rb, ra in zip(mb, ma):
        assert _same(rb, ra)


def test_async_staleness0_one_device_mesh_bit_identical():
    # degenerate mesh: the shard_map async program must match both the
    # unsharded async engine and the barrier engine (determinism contract §8)
    cb = _cfg()
    ca = dataclasses.replace(cb, sync_policy="async", max_staleness=0)
    rb = IslandOptimizer(ALGORITHMS["pso"], cb).minimize(F6, KEY)
    rm = IslandOptimizer(ALGORITHMS["pso"], ca,
                         mesh_cfg=MeshConfig(devices=1)).minimize(F6, KEY)
    ru = IslandOptimizer(ALGORITHMS["pso"], ca).minimize(F6, KEY)
    assert _same(rb, rm)
    assert _same(ru, rm)


# --- record/replay ----------------------------------------------------------

def test_recorded_schedule_replays_bit_identically():
    ca = _cfg(sync_policy="async", max_staleness=3)
    o1 = IslandOptimizer(ALGORITHMS["de"], ca, schedule=AsyncSchedule(seed=11))
    r1 = o1.minimize(F6, KEY)
    rec = o1.recorded_schedule
    assert rec is not None and rec.step is not None
    o2 = IslandOptimizer(ALGORITHMS["de"], ca, schedule=rec)
    r2 = o2.minimize(F6, KEY)
    assert _same(r1, r2)
    # replay records the same concrete masks it was fed
    assert np.array_equal(np.asarray(o2.recorded_schedule.step),
                          np.asarray(rec.step))
    assert np.array_equal(np.asarray(o2.recorded_schedule.deliver),
                          np.asarray(rec.deliver))
    # staleness bound holds on the real (non-uniform) schedule
    assert -1 <= o1.last_max_staleness <= 3


def test_async_schedule_actually_desynchronizes():
    # sanity: a sparse schedule produces a different trajectory than barrier
    cb = _cfg()
    ca = dataclasses.replace(cb, sync_policy="async", max_staleness=3)
    rb = IslandOptimizer(ALGORITHMS["de"], cb).minimize(F6, KEY)
    ra = IslandOptimizer(ALGORITHMS["de"], ca,
                         schedule=AsyncSchedule(seed=11)).minimize(F6, KEY)
    assert not np.array_equal(np.asarray(rb.history), np.asarray(ra.history))


def test_cadence_schedule_construction():
    s = AsyncSchedule.from_cadences([1, 2, 4], n_rounds=8)
    step, deliver = s.materialize(8, 3)
    assert step.shape == (8, 3) and deliver.all()
    assert step[:, 0].all()                      # cadence 1: every tick
    assert list(step[:, 2]) == [True, False, False, False] * 2


# --- mailbox edge cases -----------------------------------------------------

def test_mailbox_ring_full_overwrites_oldest():
    box = mig.mailbox_init(n_islands=2, slots=2, k=1, dim=3)
    pop = jnp.arange(2 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 3)
    fit = jnp.arange(2 * 4, dtype=jnp.float32).reshape(2, 4)
    post = jnp.ones((2,), bool)
    for tick in range(3):                       # 3 posts into 2 slots
        box = mig.mailbox_post(box, pop + tick, fit, k=1, post=post)
        box = {**box, "round_ctr": box["round_ctr"] + 1}
    # head wrapped: slot 0 holds the NEWEST batch (tick 2), slot 1 tick 1
    assert list(np.asarray(box["mbox_head"])) == [1, 1]
    assert list(np.asarray(box["mbox_tag"])[0]) == [2, 1]
    # slot 0's payload is the tick-2 emigrant (the tick-0 one is gone)
    np.testing.assert_array_equal(
        np.asarray(box["mbox_pop"])[0, 0, 0], np.asarray(pop[1, 0] + 2))


def test_mailbox_too_stale_migrant_dropped():
    box = mig.mailbox_init(n_islands=2, slots=2, k=1, dim=3)
    pop = jnp.ones((2, 4, 3), jnp.float32)
    fit = jnp.full((2, 4), 5.0, jnp.float32)
    box = mig.mailbox_post(box, pop * 0.5, fit * 0.0, k=1,
                           post=jnp.ones((2,), bool))
    # sender tagged round 0; receivers are now 4 rounds ahead
    box = {**box, "round_ctr": jnp.full((2,), 4, jnp.int32)}
    gate = jnp.ones((2,), bool)
    npop, nfit, nbox = mig.mailbox_adopt(box, pop, fit, max_staleness=2,
                                         gate=gate)
    np.testing.assert_array_equal(np.asarray(npop), np.asarray(pop))
    np.testing.assert_array_equal(np.asarray(nfit), np.asarray(fit))
    assert (np.asarray(nbox["stale_seen"]) == -1).all()   # nothing adopted
    # within the bound the same migrant IS adopted
    fresh = {**box, "round_ctr": jnp.full((2,), 2, jnp.int32)}
    npop, nfit, nbox = mig.mailbox_adopt(fresh, pop, fit, max_staleness=2,
                                         gate=gate)
    assert not np.array_equal(np.asarray(nfit), np.asarray(fit))
    assert (np.asarray(nbox["stale_seen"]) == 2).all()


@pytest.mark.parametrize("algo", ALGOS)
def test_async_single_island_is_selfloop_noop(algo):
    # n_islands=1: the mailbox would be a self-loop, so the engine keeps the
    # barrier path and async is bit-identical to it by construction
    cb = _cfg(n_islands=1, pop=24, max_evals=1500)
    ca = dataclasses.replace(cb, sync_policy="async", max_staleness=2)
    rb = IslandOptimizer(ALGORITHMS[algo], cb).minimize(F6, KEY)
    ra = IslandOptimizer(ALGORITHMS[algo], ca).minimize(F6, KEY)
    assert _same(rb, ra)


def test_async_config_validation():
    with pytest.raises(ValueError, match="sync_policy"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(sync_policy="nope"))
    with pytest.raises(ValueError, match="starvation"):
        IslandOptimizer(ALGORITHMS["de"],
                        _cfg(sync_policy="async", migration="starvation"))
    with pytest.raises(ValueError, match="max_staleness"):
        IslandOptimizer(ALGORITHMS["de"],
                        _cfg(sync_policy="async", max_staleness=-1))
    with pytest.raises(ValueError, match="AsyncSchedule"):
        IslandOptimizer(ALGORITHMS["de"], _cfg(),
                        schedule=AsyncSchedule(seed=1))


# --- property: random schedules replay exactly, staleness stays bounded -----

if given is not None:
    _CFG = _cfg(pop=8, max_evals=1500, sync_every=2,
                sync_policy="async", max_staleness=4)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.floats(0.3, 1.0), st.floats(0.3, 1.0))
    def test_random_schedules_replay_and_bound_staleness(seed, p_step,
                                                         p_deliver):
        sched = AsyncSchedule(seed=seed, step_prob=p_step,
                              deliver_prob=p_deliver)
        o1 = IslandOptimizer(ALGORITHMS["de"], _CFG, schedule=sched)
        r1 = o1.minimize(F6, KEY)
        assert -1 <= o1.last_max_staleness <= _CFG.max_staleness
        o2 = IslandOptimizer(ALGORITHMS["de"], _CFG,
                             schedule=o1.recorded_schedule)
        r2 = o2.minimize(F6, KEY)
        assert _same(r1, r2)
        assert o2.last_max_staleness == o1.last_max_staleness
