"""Cross-host federation fault-injection suite (DESIGN.md §13, ISSUE 8).

Spawns real ``opt_serve`` subprocesses over TCP-JSONL and drives them through
``launch/federate.py``. The headline contract: SIGKILL a worker mid-run and
the coordinator revives it from its checkpoint store (``--resume-dir``, PR 7
manifests) — and because every job seed and warm-routing hop is a pure
function of :class:`FederationConfig`, the finished federation's incumbent is
**identical** to an uninterrupted fixed-seed run.

Marked ``slow`` (multi-second subprocess harness); CI's federation-smoke job
runs it explicitly.
"""
import time

import numpy as np
import pytest

from repro.launch.federate import (FederationConfig, FederationCoordinator,
                                   WorkerSpec, federate)

pytestmark = pytest.mark.slow


def _cfg(tmp_path, name, **kw):
    base = dict(fn="rastrigin", dim=4, legs=2, evals_per_leg=1200,
                seed=5, pop=16, n_islands=2, sync_every=5,
                checkpoint_root=str(tmp_path / name),
                workers=(WorkerSpec(), WorkerSpec()))
    base.update(kw)
    return FederationConfig(**base)


def test_federation_two_workers_runs_and_routes(tmp_path):
    res = federate(_cfg(tmp_path, "plain"))
    assert res.revived == 0 and res.resubmitted == 0
    assert len(res.legs) == 2 and len(res.legs[0]) == 2
    assert np.isfinite(res.value) and len(res.arg) == 4
    # leg results are real per-worker jobs with distinct seeds
    vals0 = [r["value"] for r in res.legs[0]]
    assert len(set(vals0)) == 2


def test_federation_is_deterministic(tmp_path):
    r1 = federate(_cfg(tmp_path, "d1"))
    r2 = federate(_cfg(tmp_path, "d2"))
    assert r1.value == r2.value and r1.arg == r2.arg


def test_federation_heterogeneous_workers(tmp_path):
    cfg = _cfg(tmp_path, "het",
               workers=(WorkerSpec(algo="de"), WorkerSpec(algo="pso")))
    res = federate(cfg)
    assert np.isfinite(res.value) and len(res.legs) == 2


def test_federation_survives_sigkilled_worker(tmp_path):
    # uninterrupted reference
    ref = federate(_cfg(tmp_path, "ref"))
    # same federation, SIGKILL worker 1 after leg 0's submits land — it is
    # revived with --resume-dir and the run must converge to the same answer
    cfg = _cfg(tmp_path, "kill")
    coord = FederationCoordinator(cfg)

    def fault(leg):
        if leg == 0:
            time.sleep(0.3)          # let the bucket start and checkpoint
            coord.workers[1].kill()

    coord.fault_hook = fault
    coord.start()
    try:
        res = coord.run()
    finally:
        coord.close()
    assert res.revived >= 1
    assert res.value == ref.value
    assert res.arg == ref.arg
    assert [[r["value"] for r in leg] for leg in res.legs] == \
           [[r["value"] for r in leg] for leg in ref.legs]
