"""int8 gradient compression (parallel/compress.py): error bound, unbiasedness
(stochastic rounding), and tree round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep; pip install -r requirements-dev.txt")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.parallel.compress import (compress_tree, decompress_tree,
                                     dequantize, quantize)

KEY = jax.random.PRNGKey(9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
def test_quantize_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = quantize(x, jax.random.fold_in(jax.random.PRNGKey(seed), 1))
    err = jnp.abs(dequantize(q, s) - x)
    # stochastic rounding error is bounded by one quantization step
    assert float(jnp.max(err)) <= float(s) * 1.0 + 1e-6


def test_quantize_unbiased():
    """E[dequantize(quantize(x))] = x under stochastic rounding."""
    x = jnp.full((64,), 0.3)     # deliberately between grid points
    acc = jnp.zeros_like(x)
    n = 300
    for i in range(n):
        q, s = quantize(x, jax.random.fold_in(KEY, i))
        acc = acc + dequantize(q, s)
    mean = acc / n
    np.testing.assert_allclose(np.asarray(mean), 0.3, atol=2e-3)


def test_tree_roundtrip():
    tree = {"a": jax.random.normal(KEY, (32, 8)),
            "b": {"c": jax.random.normal(jax.random.fold_in(KEY, 1), (5,))}}
    q, s = compress_tree(tree, jax.random.fold_in(KEY, 2))
    out = decompress_tree(q, s)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
        assert rel < 0.02          # int8: ~1/127 relative resolution
    # payload really is int8
    assert all(x.dtype == jnp.int8 for x in jax.tree.leaves(q))
