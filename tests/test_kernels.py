"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref, registry

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("S,T,hd", [(128, 128, 64), (256, 256, 64),
                                    (128, 256, 128), (100, 200, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, T, hd, dtype):
    BH = 2
    q, k, v = _rand((BH, S, hd), dtype, 0), _rand((BH, T, hd), dtype, 1), _rand((BH, T, hd), dtype, 2)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert out.shape == (BH, S, hd)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32))) < tol


@pytest.mark.parametrize("window,softcap,causal", [(0, 0.0, True), (64, 0.0, True),
                                                   (0, 50.0, True), (0, 0.0, False),
                                                   (32, 30.0, True)])
def test_flash_attention_masks(window, softcap, causal):
    q, k, v = (_rand((2, 192, 64), k=i) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, window=window, softcap=softcap)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    assert jnp.max(jnp.abs(out - exp)) < 2e-6


@pytest.mark.parametrize("S,P,N,chunk", [(128, 32, 16, 32), (256, 64, 64, 64),
                                         (256, 64, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(S, P, N, chunk, dtype):
    BH = 3
    xh = _rand((BH, S, P), dtype, 0)
    dt = jax.nn.softplus(_rand((BH, S), k=1))
    A = -jnp.exp(_rand((BH,), k=2))
    Bm, Cm = _rand((BH, S, N), dtype, 3), _rand((BH, S, N), dtype, 4)
    out = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_ref(xh, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(exp.astype(jnp.float32)))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - exp.astype(jnp.float32)))) / scale < tol


BENCH_FNS = [n for n in registry.registered() if n != "shifted_rosenbrock"]


@pytest.mark.parametrize("fn", BENCH_FNS)
@pytest.mark.parametrize("P,D", [(8, 64), (37, 100), (130, 1000)])
def test_bench_eval(fn, P, D):
    # Sweep [-5, 5] clipped to the function's own box: michalewicz's
    # sin(i*x^2/pi)^20 loses f32 parity outside its [0, pi] domain.
    from repro.functions import get
    f = get(fn)
    pop = jax.random.uniform(jax.random.fold_in(KEY, 5), (P, D),
                             minval=max(f.lo, -5.0), maxval=min(f.hi, 5.0))
    out = ops.bench_eval(pop, fn)
    exp = ref.bench_eval_ref(pop, fn)
    rel = jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0))
    # michalewicz's ^20 power amplifies f32 sin rounding at 1000-D
    assert rel < (1e-4 if fn == "michalewicz" else 1e-5)


@pytest.mark.parametrize("fn", BENCH_FNS)
def test_bench_eval_in_domain(fn):
    """Parity on each function's own box domain (registry-driven)."""
    from repro.functions import get
    f = get(fn)
    pop = jax.random.uniform(jax.random.fold_in(KEY, 13), (33, 48),
                             minval=f.lo, maxval=f.hi)
    out = ops.bench_eval(pop, fn)
    exp = ref.bench_eval_ref(pop, fn)
    rel = jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0))
    assert rel < 1e-4, fn


def test_bench_eval_unregistered_raises():
    pop = jax.random.uniform(KEY, (8, 8))
    with pytest.raises(ValueError, match="weierstrass"):
        ops.bench_eval(pop, "weierstrass")


def test_bench_eval_shifted():
    pop = jax.random.uniform(KEY, (16, 100), minval=-100, maxval=100)
    sh = jax.random.uniform(jax.random.fold_in(KEY, 6), (100,),
                            minval=-80, maxval=80)
    out = ops.bench_eval(pop, "shifted_rosenbrock", shift=sh, bias=390.0)
    exp = ref.bench_eval_ref(pop, "shifted_rosenbrock", shift=sh, bias=390.0)
    assert jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0)) < 1e-5


@pytest.mark.parametrize("P,D", [(50, 100), (128, 1000), (99, 333)])
def test_de_step(P, D):
    pop = jax.random.uniform(KEY, (P, D), minval=-100, maxval=100)
    fit = ref.bench_eval_ref(pop, "rastrigin")
    i = jnp.arange(P)
    idx = jnp.stack([(i + 3) % P, (i + 7) % P, (i + 11) % P])
    u = jax.random.uniform(jax.random.fold_in(KEY, 9), (P, D))
    jr = jax.random.randint(jax.random.fold_in(KEY, 10), (P,), 0, D)
    a1, a2 = ops.de_step(pop, fit, idx, u, jr, fn="rastrigin")
    b1, b2 = ref.de_step_ref(pop, fit, idx, u, jr, fn="rastrigin")
    assert jnp.max(jnp.abs(a1 - b1)) < 1e-5
    assert jnp.max(jnp.abs(a2 - b2) / (jnp.abs(b2) + 1.0)) < 1e-5


def test_de_step_monotone():
    """Selection invariant: fitness never gets worse."""
    P, D = 64, 50
    pop = jax.random.uniform(KEY, (P, D), minval=-100, maxval=100)
    fit = ref.bench_eval_ref(pop, "sphere")
    i = jnp.arange(P)
    idx = jnp.stack([(i + 1) % P, (i + 5) % P, (i + 9) % P])
    u = jax.random.uniform(jax.random.fold_in(KEY, 11), (P, D))
    jr = jax.random.randint(jax.random.fold_in(KEY, 12), (P,), 0, D)
    _, nf = ops.de_step(pop, fit, idx, u, jr, fn="sphere")
    assert bool(jnp.all(nf <= fit + 1e-6))


# --- fused whole-generation kernels (ISSUE 6) --------------------------------

def _box_pop(P, D, fn, k=0):
    from repro.functions import get
    f = get(fn)
    return jax.random.uniform(jax.random.fold_in(KEY, 100 + k), (P, D),
                              minval=max(f.lo, -5.0), maxval=min(f.hi, 5.0))


@pytest.mark.parametrize("fn", ["sphere", "rastrigin", "griewank"])
@pytest.mark.parametrize("P,D", [(32, 64), (37, 100), (99, 333)])
def test_pso_step(fn, P, D):
    x = _box_pop(P, D, fn, 0)
    v = 0.1 * _rand((P, D), k=1)
    pbest = _box_pop(P, D, fn, 2)
    pbest_f = ref.bench_eval_ref(pbest, fn)
    r1 = jax.random.uniform(jax.random.fold_in(KEY, 103), (P, D))
    r2 = jax.random.uniform(jax.random.fold_in(KEY, 104), (P, D))
    gbest = pbest[jnp.argmin(pbest_f)]
    out = ops.pso_step(x, v, pbest, pbest_f, r1, r2, gbest, fn=fn, vmax=2.0)
    exp = ref.pso_step_ref(x, v, pbest, pbest_f, r1, r2, gbest, fn=fn, vmax=2.0)
    for a, b in zip(out, exp):
        assert a.shape == b.shape
        assert jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1.0)) < 1e-4


@pytest.mark.parametrize("fn", ["sphere", "rastrigin", "griewank"])
@pytest.mark.parametrize("N,D", [(16, 64), (37, 100), (99, 333)])
def test_ga_step(fn, N, D):
    p1 = _box_pop(N, D, fn, 0)
    p2 = _box_pop(N, D, fn, 1)
    slot_pop = _box_pop(N, D, fn, 2)
    slot_f = ref.bench_eval_ref(slot_pop, fn)
    cut = jax.random.randint(jax.random.fold_in(KEY, 110), (N,), 1, D)
    co = jax.random.uniform(jax.random.fold_in(KEY, 111), (N,))
    um = jax.random.uniform(jax.random.fold_in(KEY, 112), (N, D))
    nz = jax.random.normal(jax.random.fold_in(KEY, 113), (N, D))
    out = ops.ga_step(p1, p2, slot_pop, slot_f, cut, co, um, nz, fn=fn)
    exp = ref.ga_step_ref(p1, p2, slot_pop, slot_f, cut, co, um, nz, fn=fn)
    assert jnp.array_equal(out[2], exp[2])          # identical take decisions
    for a, b in zip(out[:2], exp[:2]):
        assert jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1.0)) < 1e-4


@pytest.mark.parametrize("P,D", [(32, 64), (37, 100), (99, 333)])
@pytest.mark.parametrize("use_thresh", [False, True])
def test_eval_select(P, D, use_thresh):
    fn = "rastrigin"
    pop = _box_pop(P, D, fn, 0)
    fit = ref.bench_eval_ref(pop, fn)
    trial = _box_pop(P, D, fn, 1)
    th = (2.0 * jax.random.uniform(jax.random.fold_in(KEY, 120), (P,))
          if use_thresh else None)
    out = ops.eval_select(pop, fit, trial, th, fn=fn)
    exp = ref.eval_select_ref(pop, fit, trial, th, fn=fn)
    assert jnp.array_equal(out[2], exp[2])
    for a, b in zip(out[:2], exp[:2]):
        assert jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1.0)) < 1e-4


def test_eval_select_greedy_monotone():
    P, D = 41, 30                               # padded tail: 41 -> block of 48
    pop = _box_pop(P, D, "sphere", 0)
    fit = ref.bench_eval_ref(pop, "sphere")
    trial = _box_pop(P, D, "sphere", 1)
    _, nf, _ = ops.eval_select(pop, fit, trial, None, fn="sphere")
    assert bool(jnp.all(nf <= fit + 1e-6))


@pytest.mark.parametrize("P", [5, 37, 130])
def test_padded_tail_rows_never_selected(P):
    """Explicit small pop_block forces pad rows in the last grid tile; the
    in-kernel row mask must keep them out of every selection decision."""
    from repro.kernels.bench_eval import bench_eval as _bench_eval
    from repro.kernels.de_step import de_step as _de_step
    D = 33
    pop = _box_pop(P, D, "rastrigin", 0)
    fit = ref.bench_eval_ref(pop, "rastrigin")
    out = _bench_eval(pop, "rastrigin", pop_block=8, interpret=True)
    assert out.shape == (P,)
    assert jnp.max(jnp.abs(out - fit) / (jnp.abs(fit) + 1.0)) < 1e-5
    i = jnp.arange(P)
    idx = jnp.stack([(i + 1) % P, (i + 2) % P, (i + 3) % P])
    u = jax.random.uniform(jax.random.fold_in(KEY, 130), (P, D))
    jr = jax.random.randint(jax.random.fold_in(KEY, 131), (P,), 0, D)
    np_, nf = _de_step(pop, fit, idx, u, jr, fn="rastrigin",
                       pop_block=8, interpret=True)
    ep, ef = ref.de_step_ref(pop, fit, idx, u, jr, fn="rastrigin")
    assert np_.shape == (P, D) and nf.shape == (P,)
    assert bool(jnp.all(jnp.isfinite(nf)))
    assert jnp.max(jnp.abs(np_ - ep)) < 1e-5
    assert jnp.max(jnp.abs(nf - ef) / (jnp.abs(ef) + 1.0)) < 1e-5
