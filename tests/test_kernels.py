"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref, registry

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("S,T,hd", [(128, 128, 64), (256, 256, 64),
                                    (128, 256, 128), (100, 200, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, T, hd, dtype):
    BH = 2
    q, k, v = _rand((BH, S, hd), dtype, 0), _rand((BH, T, hd), dtype, 1), _rand((BH, T, hd), dtype, 2)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert out.shape == (BH, S, hd)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32))) < tol


@pytest.mark.parametrize("window,softcap,causal", [(0, 0.0, True), (64, 0.0, True),
                                                   (0, 50.0, True), (0, 0.0, False),
                                                   (32, 30.0, True)])
def test_flash_attention_masks(window, softcap, causal):
    q, k, v = (_rand((2, 192, 64), k=i) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, window=window, softcap=softcap)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    assert jnp.max(jnp.abs(out - exp)) < 2e-6


@pytest.mark.parametrize("S,P,N,chunk", [(128, 32, 16, 32), (256, 64, 64, 64),
                                         (256, 64, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(S, P, N, chunk, dtype):
    BH = 3
    xh = _rand((BH, S, P), dtype, 0)
    dt = jax.nn.softplus(_rand((BH, S), k=1))
    A = -jnp.exp(_rand((BH,), k=2))
    Bm, Cm = _rand((BH, S, N), dtype, 3), _rand((BH, S, N), dtype, 4)
    out = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_ref(xh, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(exp.astype(jnp.float32)))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - exp.astype(jnp.float32)))) / scale < tol


BENCH_FNS = [n for n in registry.registered() if n != "shifted_rosenbrock"]


@pytest.mark.parametrize("fn", BENCH_FNS)
@pytest.mark.parametrize("P,D", [(8, 64), (37, 100), (130, 1000)])
def test_bench_eval(fn, P, D):
    # Sweep [-5, 5] clipped to the function's own box: michalewicz's
    # sin(i*x^2/pi)^20 loses f32 parity outside its [0, pi] domain.
    from repro.functions import get
    f = get(fn)
    pop = jax.random.uniform(jax.random.fold_in(KEY, 5), (P, D),
                             minval=max(f.lo, -5.0), maxval=min(f.hi, 5.0))
    out = ops.bench_eval(pop, fn)
    exp = ref.bench_eval_ref(pop, fn)
    rel = jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0))
    # michalewicz's ^20 power amplifies f32 sin rounding at 1000-D
    assert rel < (1e-4 if fn == "michalewicz" else 1e-5)


@pytest.mark.parametrize("fn", BENCH_FNS)
def test_bench_eval_in_domain(fn):
    """Parity on each function's own box domain (registry-driven)."""
    from repro.functions import get
    f = get(fn)
    pop = jax.random.uniform(jax.random.fold_in(KEY, 13), (33, 48),
                             minval=f.lo, maxval=f.hi)
    out = ops.bench_eval(pop, fn)
    exp = ref.bench_eval_ref(pop, fn)
    rel = jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0))
    assert rel < 1e-4, fn


def test_bench_eval_unregistered_raises():
    pop = jax.random.uniform(KEY, (8, 8))
    with pytest.raises(ValueError, match="weierstrass"):
        ops.bench_eval(pop, "weierstrass")


def test_bench_eval_shifted():
    pop = jax.random.uniform(KEY, (16, 100), minval=-100, maxval=100)
    sh = jax.random.uniform(jax.random.fold_in(KEY, 6), (100,),
                            minval=-80, maxval=80)
    out = ops.bench_eval(pop, "shifted_rosenbrock", shift=sh, bias=390.0)
    exp = ref.bench_eval_ref(pop, "shifted_rosenbrock", shift=sh, bias=390.0)
    assert jnp.max(jnp.abs(out - exp) / (jnp.abs(exp) + 1.0)) < 1e-5


@pytest.mark.parametrize("P,D", [(50, 100), (128, 1000), (99, 333)])
def test_de_step(P, D):
    pop = jax.random.uniform(KEY, (P, D), minval=-100, maxval=100)
    fit = ref.bench_eval_ref(pop, "rastrigin")
    i = jnp.arange(P)
    idx = jnp.stack([(i + 3) % P, (i + 7) % P, (i + 11) % P])
    u = jax.random.uniform(jax.random.fold_in(KEY, 9), (P, D))
    jr = jax.random.randint(jax.random.fold_in(KEY, 10), (P,), 0, D)
    a1, a2 = ops.de_step(pop, fit, idx, u, jr, fn="rastrigin")
    b1, b2 = ref.de_step_ref(pop, fit, idx, u, jr, fn="rastrigin")
    assert jnp.max(jnp.abs(a1 - b1)) < 1e-5
    assert jnp.max(jnp.abs(a2 - b2) / (jnp.abs(b2) + 1.0)) < 1e-5


def test_de_step_monotone():
    """Selection invariant: fitness never gets worse."""
    P, D = 64, 50
    pop = jax.random.uniform(KEY, (P, D), minval=-100, maxval=100)
    fit = ref.bench_eval_ref(pop, "sphere")
    i = jnp.arange(P)
    idx = jnp.stack([(i + 1) % P, (i + 5) % P, (i + 9) % P])
    u = jax.random.uniform(jax.random.fold_in(KEY, 11), (P, D))
    jr = jax.random.randint(jax.random.fold_in(KEY, 12), (P,), 0, D)
    _, nf = ops.de_step(pop, fit, idx, u, jr, fn="sphere")
    assert bool(jnp.all(nf <= fit + 1e-6))
