"""Hybrid memetic layer tests (DESIGN.md §6–§7): batched polish semantics and
eval accounting, in-scan hybrid determinism/parity across minimize /
minimize_many / host-stepped paths, shape-class separation, the two-stage
pipeline, and the JSONL service path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALGORITHMS, IslandConfig, IslandOptimizer, OptRequest,
                        ShapeBucketScheduler, explore_then_polish,
                        explore_then_polish_many)
from repro.functions import get
from repro.launch.opt_serve import OptimizationService
from repro.optim.descent import (PolishConfig, make_polish,
                                 polish_evals_per_point)

KEY = jax.random.PRNGKey(7)
METHODS = ("asd", "fcg", "avd", "bfgs")

HYBRID = dict(polish="asd", polish_every=2, polish_topk=3, polish_steps=2)


def _island_cfg(**kw):
    base = dict(n_islands=2, pop=16, dim=6, sync_every=5, migration="ring",
                max_evals=5000)
    base.update(kw)
    return IslandConfig(**base)


def _starts(f, k, dim, key=KEY):
    xs = jax.random.uniform(key, (k, dim), minval=f.lo, maxval=f.hi)
    return xs, jax.vmap(f.fn)(xs)


# --- polish primitive --------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_polish_monotone_and_jit_safe(method):
    f = get("rosenbrock")
    xs, fs = _starts(f, 5, 6)
    cfg = PolishConfig(method=method, steps=4)
    polish = make_polish(f, None, 6, cfg)
    xs2, fs2 = polish(xs, fs)                      # eager
    assert bool(jnp.all(fs2 <= fs))                # monotone by construction
    assert bool(jnp.any(fs2 < fs))                 # and actually descends
    jxs2, jfs2 = jax.jit(polish)(xs, fs)           # jitted: same trajectory
    np.testing.assert_array_equal(np.asarray(fs2), np.asarray(jfs2))
    np.testing.assert_array_equal(np.asarray(xs2), np.asarray(jxs2))


@pytest.mark.parametrize("method", METHODS)
def test_polish_eval_accounting_is_exact(method):
    """The evaluator sees exactly polish_evals_per_point(dim)·K rows per
    step — counted at trace time (scan traces its body once, so the counter
    observes one step's cost)."""
    f = get("sphere")
    dim, k = 5, 3
    xs, fs = _starts(f, k, dim)
    cfg = PolishConfig(method=method, steps=4)
    rows = [0]

    def counting_eval(pop):
        rows[0] += pop.shape[0]
        return jax.vmap(f.fn)(pop)

    make_polish(f, counting_eval, dim, cfg)(xs, fs)
    per_step = polish_evals_per_point(dim, cfg) // cfg.steps
    assert rows[0] == k * per_step


@pytest.mark.parametrize("method", METHODS)
def test_polish_batched_matches_single_start(method):
    """Polishing K starts in one batch follows the same trajectory as
    polishing each start alone. Rows are arithmetically independent, but the
    batch shape changes XLA's reduction fusion, so f32 noise (~1e-7) can
    compound across steps — parity is trajectory-level, not bit-level."""
    f = get("levy")
    cfg = PolishConfig(method=method, steps=3)
    polish = make_polish(f, None, 6, cfg)
    xs, fs = _starts(f, 4, 6)
    bx, bf = polish(xs, fs)
    for i in range(4):
        sx, sf = polish(xs[i:i + 1], fs[i:i + 1])
        np.testing.assert_allclose(np.asarray(sx[0]), np.asarray(bx[i]),
                                   rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(float(sf[0]), float(bf[i]),
                                   rtol=1e-3, atol=1e-5)


def test_polish_config_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown polish method"):
        PolishConfig(method="adam")


# --- in-scan hybrid engine ---------------------------------------------------

def test_hybrid_fixed_seed_determinism():
    f = get("rosenbrock")
    r1 = IslandOptimizer(ALGORITHMS["de"], _island_cfg(**HYBRID)).minimize(f, KEY)
    r2 = IslandOptimizer(ALGORITHMS["de"], _island_cfg(**HYBRID)).minimize(f, KEY)
    assert r1.value == r2.value and r1.n_evals == r2.n_evals
    np.testing.assert_array_equal(np.asarray(r1.history), np.asarray(r2.history))
    np.testing.assert_array_equal(np.asarray(r1.arg), np.asarray(r2.arg))


def test_hybrid_budget_counts_polish_evals():
    """Polish work is charged to max_evals: the hybrid stays within budget
    and runs measurably fewer generations than the plain config."""
    f = get("rosenbrock")
    plain = IslandOptimizer(ALGORITHMS["de"], _island_cfg()).minimize(f, KEY)
    hyb = IslandOptimizer(ALGORITHMS["de"], _island_cfg(**HYBRID)).minimize(f, KEY)
    assert hyb.n_evals <= 5000
    assert hyb.n_gens < plain.n_gens
    # exact accounting: init + rounds*per_round + polish events*per_event
    cfg = _island_cfg(**HYBRID)
    pcfg = PolishConfig(method="asd", steps=cfg.polish_steps)
    per_event = (polish_evals_per_point(cfg.dim, pcfg)
                 * cfg.polish_topk * cfg.n_islands)
    n_rounds = hyb.n_gens // cfg.sync_every
    per_round = cfg.pop * cfg.n_islands * cfg.sync_every
    expect = (cfg.pop * cfg.n_islands + n_rounds * per_round
              + (n_rounds // cfg.polish_every) * per_event)
    assert hyb.n_evals == expect


def test_hybrid_minimize_many_bit_identical():
    """Jobs-axis hybrid trajectories == standalone hybrid minimize."""
    f = get("rastrigin")
    cfg = _island_cfg(**HYBRID)
    seq = [IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, jax.random.PRNGKey(s))
           for s in (0, 4)]
    many = IslandOptimizer(ALGORITHMS["de"], cfg).minimize_many(
        f, jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(4)]))
    for m, s in zip(many, seq):
        assert m.value == s.value and m.n_evals == s.n_evals
        assert bool(jnp.all(m.arg == s.arg))
        np.testing.assert_array_equal(np.asarray(m.history),
                                      np.asarray(s.history))


def test_hybrid_host_stepped_matches_device_resident():
    f = get("sphere")
    cfg = _island_cfg(**HYBRID)
    dev = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, KEY)
    seen = []
    host = IslandOptimizer(ALGORITHMS["de"], cfg,
                           round_callback=lambda r, a, v: seen.append(r))
    res = host.minimize(f, KEY)
    assert res.value == dev.value and res.n_evals == dev.n_evals
    np.testing.assert_array_equal(np.asarray(dev.history), res.history)
    assert len(seen) == len(res.history)


@pytest.mark.parametrize("method", ("fcg", "avd"))
def test_hybrid_other_polish_methods_run(method):
    f = get("griewank")
    cfg = _island_cfg(polish=method, polish_every=2, polish_topk=2,
                      polish_steps=2)
    res = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, KEY)
    assert np.isfinite(res.value) and res.n_evals <= 5000


def test_hybrid_through_pallas_backend():
    """Polish gradients/ladders ride the same pluggable evaluator as
    generation steps: the whole hybrid run works on the pallas backend
    (interpret mode off-TPU), budget accounting unchanged."""
    from repro.core import ExecutorConfig
    f = get("rastrigin")
    cfg = _island_cfg(max_evals=3000, **HYBRID)
    res = IslandOptimizer(ALGORITHMS["de"], cfg,
                          exec_cfg=ExecutorConfig(backend="pallas")).minimize(
        f, KEY)
    xla = IslandOptimizer(ALGORITHMS["de"], cfg).minimize(f, KEY)
    assert np.isfinite(res.value) and res.n_evals == xla.n_evals <= 3000


# --- shape-class / scheduler / service --------------------------------------

def test_polish_params_join_shape_class():
    base = dict(fn="sphere", dim=6, pop=16, max_evals=4000)
    plain = OptRequest(**base)
    hybrid = OptRequest(**base, polish="asd")
    assert plain.shape_class() != hybrid.shape_class()
    assert (OptRequest(**base, polish="asd", polish_topk=2).shape_class()
            != hybrid.shape_class())
    assert (OptRequest(**base, polish="asd", seed=9).shape_class()
            == hybrid.shape_class())


def test_scheduler_hybrid_bucket_parity():
    base = dict(fn="rosenbrock", dim=6, pop=16, n_islands=2, sync_every=5,
                max_evals=4000)
    sched = ShapeBucketScheduler()
    jid_p = sched.submit(OptRequest(**base))
    jid_h = sched.submit(OptRequest(**base, **HYBRID))
    assert len(sched.pending_buckets()) == 2     # hybrid != plain bucket
    sched.flush()
    assert sched.n_dispatches == 2
    got = sched.result(jid_h).result
    direct = IslandOptimizer(
        ALGORITHMS["de"],
        _island_cfg(max_evals=4000, **HYBRID)).minimize(
            get("rosenbrock"), jax.random.PRNGKey(0))
    assert got.value == direct.value and got.n_evals == direct.n_evals
    assert sched.result(jid_p).status == "done"


def test_service_hybrid_jsonl_roundtrip():
    svc = OptimizationService()
    r = svc.handle({"op": "submit", "request": {
        "fn": "sphere", "dim": 4, "pop": 16, "max_evals": 3000, "seed": 1,
        "polish": "asd", "polish_every": 2, "polish_topk": 2,
        "polish_steps": 2}})
    out = svc.handle({"op": "result", "id": r["id"]})
    assert out["status"] == "done" and out["n_evals"] <= 3000


# --- two-stage pipeline ------------------------------------------------------

def test_explore_then_polish_improves_and_accounts():
    f = get("rosenbrock")
    opt = IslandOptimizer(ALGORITHMS["de"], _island_cfg())
    base = opt.minimize(f, KEY)
    pcfg = PolishConfig(steps=8)
    res = explore_then_polish(opt, f, KEY, pcfg)
    assert res.value <= base.value
    assert res.n_evals == base.n_evals + polish_evals_per_point(6, pcfg)


def test_explore_then_polish_many_matches_single():
    f = get("rosenbrock")
    opt = IslandOptimizer(ALGORITHMS["de"], _island_cfg())
    pcfg = PolishConfig(steps=6)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 2, 5)])
    many = explore_then_polish_many(opt, f, keys, pcfg)
    for k, m in zip((0, 2, 5), many):
        single = explore_then_polish(opt, f, jax.random.PRNGKey(k), pcfg)
        np.testing.assert_allclose(m.value, single.value, rtol=1e-6)
        assert m.n_evals == single.n_evals
