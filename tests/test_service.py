"""Service-hardening tests (DESIGN.md §12): streaming progress, cooperative
cancellation, priority lanes, backpressure load-shed, checkpoint/resume, and
the fault-injection + soak layer that proves them.

Three fault surfaces are exercised:
  * in-process "kill" via the scheduler's ``fault_hook`` raising
    :class:`AbandonRun` — a worker walks away mid-run leaving checkpoints
    and job records exactly as a SIGKILL would;
  * a real SIGKILL of the TCP server subprocess, restarted with
    ``--resume-dir`` (the paper's network-of-JVMs restart story);
  * a checkpoint with a corrupted checksum, which must be rejected cleanly.

The resume contract is *bit-identity*: a killed-and-resumed fixed-seed run
must produce the same incumbent (value, argument, eval/gen accounting and
per-round history) as an uninterrupted run.

Only the Hypothesis property test is gated on the dev-only ``hypothesis``
dependency (the ``tests/test_optim.py`` convention)."""
import json
import os
import pathlib
import random
import signal
import socket
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (AbandonRun, OptRequest, SchedulerOverloaded,
                        ShapeBucketScheduler, UnknownJob)
from repro.launch.opt_serve import OptimizationService

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:       # dev-only dep; pip install -r requirements-dev.txt
    given = None


def _req(seed=0, **kw):
    base = dict(fn="sphere", algo="de", dim=4, pop=16, n_islands=2,
                sync_every=5, max_evals=1500, migration="ring")
    base.update(kw)
    return OptRequest(seed=seed, **base)


def _long_req(seed=3, **kw):
    """Many cheap sync rounds — plenty of boundaries to stream/cancel/
    checkpoint at. 2 islands * pop 16 * sync_every 1 = 32 evals/round."""
    base = dict(fn="rastrigin", algo="de", dim=6, pop=16, n_islands=2,
                sync_every=1, max_evals=32 + 32 * 120, migration="ring")
    base.update(kw)
    return OptRequest(seed=seed, **base)


def _uninterrupted(req: OptRequest):
    """Reference result: the same request through a fresh blocking scheduler."""
    sched = ShapeBucketScheduler()
    jid = sched.submit(req)
    return sched.result(jid).result


# --- streaming progress ------------------------------------------------------

def test_poll_streams_round_progress_while_running():
    """With a worker pool, pollers see round/best_val/evals advance while the
    bucket is still running — the submit/poll/result loop is no longer blind
    between submit and done."""
    sched = ShapeBucketScheduler(workers=1)
    jid = sched.submit(_long_req())
    sched.flush()
    seen = []
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:
        r = sched.poll(jid)
        if r.status == "done":
            break
        if r.status == "running" and r.round is not None:
            seen.append((r.round, r.best_val, r.evals_done, r.n_rounds))
        time.sleep(0.002)
    resp = sched.result(jid)
    assert resp.status == "done"
    assert seen, "never observed streamed progress while running"
    rounds = [s[0] for s in seen]
    assert rounds == sorted(rounds)                  # round counter advances
    assert all(s[3] == seen[0][3] for s in seen)     # n_rounds is stable
    assert all(0 < s[0] <= s[3] for s in seen)
    assert all(s[1] is not None and s[2] > 0 for s in seen)
    # incumbent never worsens round-over-round (DE keeps the best)
    vals = [s[1] for s in seen]
    assert all(b <= a + 1e-12 for a, b in zip(vals, vals[1:]))
    # final record carries the full-budget accounting
    assert resp.result.n_evals == _uninterrupted(_long_req()).n_evals
    sched.close()


def test_stepped_run_bit_identical_to_blocking_reference():
    """The pool's host-stepped bucket runner replays minimize_many's exact
    trajectory: value, argument and per-round history all match."""
    req = _long_req(seed=11)
    ref = _uninterrupted(req)
    sched = ShapeBucketScheduler(workers=1)
    jid = sched.submit(req)
    sched.flush()
    got = sched.result(jid).result
    assert got.value == ref.value
    assert np.array_equal(np.asarray(got.arg), np.asarray(ref.arg))
    assert np.array_equal(np.asarray(got.history), np.asarray(ref.history))
    assert got.n_evals == ref.n_evals and got.n_gens == ref.n_gens
    sched.close()


# --- cancellation ------------------------------------------------------------

def test_cancel_running_job_returns_partial_result():
    sched = ShapeBucketScheduler(workers=1)
    jid = sched.submit(_long_req())
    sched.flush()
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:      # wait for a round boundary so the
        r = sched.poll(jid)                 # run is provably preemptible
        if r.status == "running" and (r.round or 0) >= 1:
            break
        assert r.status != "done", "job finished before it could be cancelled"
        time.sleep(0.002)
    reply = sched.cancel(jid)
    assert reply["status"] in ("cancelling", "cancelled")
    resp = sched.result(jid)
    assert resp.status == "cancelled"
    assert resp.result is not None                    # partial incumbent
    assert 0 < resp.result.n_gens < _uninterrupted(_long_req()).n_gens
    assert resp.result.n_evals < _long_req().max_evals
    assert len(resp.result.history) == resp.round
    sched.close()


def test_cancel_queued_job_withdraws_it():
    sched = ShapeBucketScheduler(workers=1)
    jid = sched.submit(_req())
    reply = sched.cancel(jid)
    assert reply == {"id": jid, "status": "cancelled"}
    assert sched.poll(jid).status == "cancelled"
    assert sched.poll(jid).result is None             # never ran
    assert sched.pending_buckets() == []              # bucket emptied


def test_cancel_unknown_and_finished_ids_are_structured():
    svc = OptimizationService()
    assert svc.handle({"op": "cancel", "id": "ghost"}) == {
        "error": "unknown-id", "id": "ghost"}
    r = svc.handle({"op": "submit", "request":
                    {"fn": "sphere", "dim": 3, "pop": 8, "max_evals": 400}})
    svc.handle({"op": "flush"})
    reply = svc.handle({"op": "cancel", "id": r["id"]})
    assert reply["error"] == "already-finished" and reply["status"] == "done"
    with pytest.raises(UnknownJob):
        svc.scheduler.cancel("ghost")


# --- priority lanes + backpressure ------------------------------------------

def test_priority_lane_orders_bucket_execution():
    """While the single worker is pinned on a blocker bucket, a high-priority
    bucket enqueued AFTER a low-priority one must run first."""
    started, release, order = threading.Event(), threading.Event(), []

    def hook(key, r):
        order.append(key)
        if key == blocker_key and r == 1:
            started.set()
            release.wait(120)

    sched = ShapeBucketScheduler(workers=1, fault_hook=hook)
    blocker = _long_req(seed=0)
    blocker_key = blocker.shape_class()
    sched.submit(blocker)
    sched.flush()
    assert started.wait(120)                       # worker now provably pinned
    lo = sched.submit(_req(seed=1, dim=5), priority=0)
    hi = sched.submit(_req(seed=1, dim=6), priority=9)
    sched.flush()                                  # both land on the heap
    release.set()
    assert sched.result(lo).status == "done"
    assert sched.result(hi).status == "done"
    keys = [k for k in order
            if k in (_req(dim=5).shape_class(), _req(dim=6).shape_class())]
    assert keys, "neither prioritized bucket ever ran"
    assert keys[0] == _req(dim=6).shape_class()    # high priority went first
    sched.close()


def test_backpressure_sheds_load_with_retry_after():
    started, release = threading.Event(), threading.Event()

    def hook(key, r):
        started.set()
        release.wait(120)

    sched = ShapeBucketScheduler(workers=1, max_pending=2, fault_hook=hook)
    svc = OptimizationService(scheduler=sched)
    blocker = sched.submit(_long_req())
    sched.flush()
    assert started.wait(120)                       # worker pinned on round 1
    sched.submit(_req(seed=1))
    sched.submit(_req(seed=2))
    with pytest.raises(SchedulerOverloaded) as ei:
        sched.submit(_req(seed=3))
    assert ei.value.retry_after_ms > 0
    reply = svc.handle({"op": "submit",
                        "request": {"fn": "sphere", "dim": 4, "pop": 16,
                                    "n_islands": 2, "max_evals": 1500,
                                    "sync_every": 5, "seed": 4}})
    assert reply["error"] == "overloaded" and reply["retry_after_ms"] > 0
    assert sched.stats()["shed"] == 2
    release.set()
    assert sched.drain(timeout=120)
    assert sched.result(blocker).status == "done"
    sched.close()


# --- concurrency / soak ------------------------------------------------------

def test_soak_concurrent_submit_poll_cancel_no_lost_responses():
    """N submitter threads (mixed shapes) race an aggressive poller and a
    canceller against a 2-worker pool: every job reaches a final status, a
    fetched result never reappears (fetch-once), and no reply is ever a
    traceback-shaped surprise."""
    svc = OptimizationService(workers=2, max_batch=4, flush_ms=5.0)
    shapes = [dict(fn="sphere", dim=3, pop=8, n_islands=1, max_evals=400),
              dict(fn="rastrigin", dim=4, pop=8, n_islands=2, max_evals=600,
                   sync_every=2),
              dict(fn="sphere", dim=5, pop=16, n_islands=2, max_evals=800,
                   sync_every=2)]
    results, errors = {}, []
    known_ids, mu = [], threading.Lock()
    stop = threading.Event()

    def submitter(t):
        rng = random.Random(t)
        for i in range(5):
            req = dict(shapes[(t + i) % len(shapes)], seed=rng.randrange(99))
            r = svc.handle({"op": "submit", "request": req})
            if "error" in r:
                errors.append(("submit", r))
                continue
            with mu:
                known_ids.append(r["id"])
            out = svc.handle({"op": "result", "id": r["id"]})
            with mu:
                if r["id"] in results:
                    errors.append(("double-result", r["id"]))
                results[r["id"]] = out
            # fetch-once eviction: a second result is a structured error
            again = svc.handle({"op": "result", "id": r["id"]})
            if again.get("error") != "unknown-id":
                errors.append(("no-evict", again))

    def poller():
        rng = random.Random(1234)
        while not stop.is_set():
            with mu:
                ids = list(known_ids)
            if ids:
                reply = svc.handle({"op": "poll", "id": rng.choice(ids)})
                ok = ("status" in reply) or (reply.get("error") == "unknown-id")
                if not ok:
                    errors.append(("poll", reply))
            svc.handle({"op": "status"})
            time.sleep(0.001)

    def canceller():
        req = dict(fn="rastrigin", dim=6, pop=16, n_islands=2, sync_every=1,
                   max_evals=32 + 32 * 150, seed=7)
        r = svc.handle({"op": "submit", "request": req})
        svc.handle({"op": "flush"})
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            p = svc.handle({"op": "poll", "id": r["id"]})
            if p.get("status") in ("done", "cancelled") or p.get("round"):
                break
            time.sleep(0.002)
        svc.handle({"op": "cancel", "id": r["id"]})
        out = svc.handle({"op": "result", "id": r["id"]})
        if out.get("status") not in ("cancelled", "done"):
            errors.append(("cancel", out))

    threads = ([threading.Thread(target=submitter, args=(t,)) for t in range(6)]
               + [threading.Thread(target=canceller)])
    pollt = threading.Thread(target=poller, daemon=True)
    pollt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "soak thread hung"
    stop.set()
    pollt.join(timeout=10)
    assert errors == []
    assert len(results) == 30                       # no lost responses
    assert all(r.get("status") == "done" and "value" in r
               for r in results.values())
    stats = svc.handle({"op": "stats"})
    assert stats["cancelled"] >= 0 and stats["workers"] == 2
    svc.scheduler.close()


# --- checkpoint / resume (in-process fault injection) -----------------------

def _abandon_at(round_no, key_filter=None):
    """fault_hook raising AbandonRun at a round boundary — the in-process
    SIGKILL: the worker walks away leaving checkpoints + job records."""
    fired = threading.Event()

    def hook(key, r):
        if key_filter is not None and key != key_filter:
            return
        if r == round_no:
            fired.set()
            raise AbandonRun(f"injected kill at round {r}")

    return hook, fired


def test_kill_and_resume_is_bit_identical(tmp_path):
    req = _long_req(seed=5)
    ref = _uninterrupted(req)

    hook, fired = _abandon_at(6)
    sched = ShapeBucketScheduler(workers=1, checkpoint_dir=str(tmp_path),
                                 checkpoint_every=2, fault_hook=hook)
    jid = sched.submit(req)
    sched.flush()
    assert fired.wait(timeout=120), "fault hook never fired"
    time.sleep(0.05)                       # let the worker unwind
    assert sched.poll(jid).status == "running"      # orphaned, like a SIGKILL
    run_dirs = [d for d in os.listdir(tmp_path) if d.startswith("run_")]
    assert len(run_dirs) == 1, "expected exactly one interrupted run on disk"
    sched.close()

    sched2 = ShapeBucketScheduler()        # fresh process, blocking mode
    summary = sched2.resume(str(tmp_path))
    assert summary["failed"] == []
    assert [jid] == summary["resumed"][0]["jobs"]
    assert summary["resumed"][0]["round"] == 6      # latest committed snapshot
    got = sched2.result(jid)
    assert got.status == "done"
    assert got.result.value == ref.value                        # bit-identical
    assert np.array_equal(np.asarray(got.result.arg), np.asarray(ref.arg))
    assert np.array_equal(np.asarray(got.result.history),
                          np.asarray(ref.history))
    assert got.result.n_evals == ref.n_evals
    assert got.result.n_gens == ref.n_gens
    # completed runs clean their snapshots: nothing left to double-resume
    assert [d for d in os.listdir(tmp_path) if d.startswith("run_")] == []
    assert sched2.stats()["resumed"] == 1


def test_corrupted_checkpoint_is_rejected_cleanly(tmp_path):
    hook, fired = _abandon_at(6)
    sched = ShapeBucketScheduler(workers=1, checkpoint_dir=str(tmp_path),
                                 checkpoint_every=2, fault_hook=hook)
    jid = sched.submit(_long_req(seed=5))
    sched.flush()
    assert fired.wait(timeout=120)
    time.sleep(0.05)
    sched.close()
    run_dir = next(tmp_path.glob("run_*"))
    step_dir = sorted(run_dir.glob("step_*"))[-1]
    leaf = sorted(step_dir.glob("leaf_*.npy"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[-4] ^= 0xFF                        # flip payload bits: checksum breaks
    leaf.write_bytes(bytes(raw))

    sched2 = ShapeBucketScheduler()
    summary = sched2.resume(str(tmp_path))
    assert summary["resumed"] == []
    assert len(summary["failed"]) == 1
    assert "checksum" in summary["failed"][0]["error"]
    # the job comes back as a structured error, and the scheduler still works
    resp = sched2.poll(jid)
    assert resp.status == "error" and "checkpoint" in resp.error
    assert sched2.stats()["resume_failed"] == 1
    ok = sched2.submit(_req())
    assert sched2.result(ok).status == "done"


# --- SIGKILL the TCP server (subprocess harness) ----------------------------

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _start_server(extra_args, timeout=120):
    """Launch opt_serve --tcp 0 in a subprocess; returns (proc, port)."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.opt_serve", "--tcp", "0",
         *extra_args],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu"),
    )
    port, lines = None, []
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        line = proc.stderr.readline()
        if not line:
            if proc.poll() is not None:
                break
            continue
        lines.append(line)
        if "listening on" in line:
            port = int(line.rsplit(":", 1)[1])
            break
    if port is None:
        proc.kill()
        raise RuntimeError(f"server never came up: {''.join(lines)}")
    return proc, port


class _Client:
    """Minimal JSONL-over-TCP client for the subprocess harness."""

    def __init__(self, port):
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=300)
        self.f = self.sock.makefile("rw")

    def call(self, msg):
        self.f.write(json.dumps(msg) + "\n")
        self.f.flush()
        return json.loads(self.f.readline())

    def close(self):
        self.sock.close()


@pytest.mark.slow
def test_sigkill_tcp_server_resume_bit_identical(tmp_path):
    """The real thing: SIGKILL the serving process mid-run, restart with
    --resume-dir, and the resumed job's final incumbent is bit-identical to
    an uninterrupted fixed-seed run."""
    req = dict(fn="rastrigin", algo="de", dim=6, pop=16, n_islands=2,
               sync_every=1, max_evals=32 + 32 * 800, seed=13,
               migration="ring")
    ref = _uninterrupted(OptRequest(**req))
    ckpt = str(tmp_path / "ckpt")

    proc, port = _start_server(["--workers", "1", "--flush-ms", "10",
                                "--checkpoint-dir", ckpt,
                                "--checkpoint-every", "2"])
    try:
        cl = _Client(port)
        sub = cl.call({"op": "submit", "request": req})
        jid = sub["id"]
        deadline = time.monotonic() + 180
        while time.monotonic() < deadline:
            p = cl.call({"op": "poll", "id": jid})
            assert p.get("status") != "done", \
                "job finished before the kill landed; raise max_evals"
            if p.get("round", 0) >= 10:
                break
            time.sleep(0.01)
        else:
            pytest.fail("never saw enough progress to kill mid-run")
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
        cl.close()
    finally:
        if proc.poll() is None:
            proc.kill()

    assert any(d.startswith("run_") for d in os.listdir(ckpt)), \
        "no checkpoint survived the kill"
    proc2, port2 = _start_server(["--workers", "1", "--resume-dir", ckpt])
    try:
        cl2 = _Client(port2)
        out = cl2.call({"op": "result", "id": jid})
        assert out["status"] == "done"
        assert out["value"] == float(ref.value)                # bit-identical
        assert out["arg"] == [float(v) for v in np.asarray(ref.arg).ravel()]
        assert out["n_evals"] == ref.n_evals
        assert out["n_gens"] == ref.n_gens
        # a second fetch is evicted; stats show the resume happened
        assert cl2.call({"op": "result", "id": jid})["error"] == "unknown-id"
        assert cl2.call({"op": "stats"})["resumed"] == 1
        assert cl2.call({"op": "quit"}) == {"bye": True}
        cl2.close()
    finally:
        proc2.kill()


# --- protocol regressions (satellite fixes) ---------------------------------

def test_result_unknown_id_is_structured_not_a_keyerror():
    svc = OptimizationService()
    assert svc.handle({"op": "result", "id": "nope"}) == {
        "error": "unknown-id", "id": "nope"}
    # evicted ids degrade to the same structured error
    r = svc.handle({"op": "submit", "request":
                    {"fn": "sphere", "dim": 3, "pop": 8, "max_evals": 400}})
    assert svc.handle({"op": "result", "id": r["id"]})["status"] == "done"
    assert svc.handle({"op": "result", "id": r["id"]}) == {
        "error": "unknown-id", "id": r["id"]}


def test_status_op_lists_per_bucket_counts():
    svc = OptimizationService(max_batch=100, flush_ms=1e6)
    for seed in range(3):
        svc.handle({"op": "submit", "request":
                    {"fn": "sphere", "dim": 4, "pop": 16, "n_islands": 2,
                     "sync_every": 5, "max_evals": 1500, "seed": seed}})
    svc.handle({"op": "submit", "request":
                {"fn": "rastrigin", "dim": 5, "pop": 16, "max_evals": 900}})
    out = svc.handle({"op": "status"})
    assert len(out["buckets"]) == 2
    by_fn = {k.split("|")[0]: v for k, v in out["buckets"].items()}
    assert by_fn["sphere"] == {"counts": {"queued": 3},
                               "sync_policy": "barrier"}
    assert by_fn["rastrigin"] == {"counts": {"queued": 1},
                                  "sync_policy": "barrier"}
    assert out["queue_depth"] == 0
    svc.handle({"op": "flush"})
    out = svc.handle({"op": "status"})
    assert {k.split("|")[0]: v["counts"] for k, v in
            out["buckets"].items()} == {
        "sphere": {"done": 3}, "rastrigin": {"done": 1}}
    json.dumps(out)                                  # JSONL-serializable


def test_status_op_reports_sync_policy_and_queue_depth():
    # Satellite regression (ISSUE 8): the status op must expose each
    # bucket's engine sync policy and the worker-pool queue depth — before
    # the fix it carried only the lifecycle counts.
    svc = OptimizationService(max_batch=100, flush_ms=1e6)
    svc.handle({"op": "submit", "request":
                {"fn": "sphere", "dim": 4, "pop": 16, "n_islands": 2,
                 "sync_policy": "async", "max_staleness": 2,
                 "sync_every": 5, "max_evals": 1500, "seed": 0}})
    svc.handle({"op": "submit", "request":
                {"fn": "sphere", "dim": 4, "pop": 16, "max_evals": 900}})
    out = svc.handle({"op": "status"})
    assert "queue_depth" in out and out["queue_depth"] == 0
    policies = sorted(v["sync_policy"] for v in out["buckets"].values())
    assert policies == ["async", "barrier"]
    # async vs barrier never share a bucket: sync_policy is shape-class
    assert len(out["buckets"]) == 2
    json.dumps(out)


# --- shape-class properties (hypothesis, test_optim.py conventions) ---------

_FIELD_VALUES = {
    "fn": ["sphere", "rastrigin", "rosenbrock"],
    "algo": ["de", "pso", "ga"],
    "dim": [2, 4, 8, 16],
    "max_evals": [500, 2000, 10_000],
    "pop": [8, 16, 64],
    "n_islands": [1, 2, 4],
    "sync_every": [1, 5, 10],
    "migration": ["ring", "starvation", "none"],
    "n_migrants": [0, 1, 2],
    "share_incumbent": [False, True],
    "backend": ["xla", "pallas"],
    "devices": [1, 2],
    "polish": ["none", "asd", "fcg"],
    "polish_every": [1, 2],
    "polish_topk": [2, 4],
    "polish_steps": [1, 3],
    "params": [{}, {"F": 0.6}, {"F": 0.6, "CR": 0.8}],
    "sync_policy": ["barrier", "async"],
    "max_staleness": [0, 2],
    "warm": [[], [[0.1, 0.2]], [[0.1, 0.2], [0.3, 0.4]]],
}

if given is not None:
    _fields = st.fixed_dictionaries({
        k: st.sampled_from(v) for k, v in _FIELD_VALUES.items()})

    @settings(max_examples=40, deadline=None)
    @given(_fields, st.integers(0, 2**31 - 1), st.randoms())
    def test_shape_class_stable_under_field_reordering(d, seed, rng):
        items = list(dict(d, seed=seed).items())
        rng.shuffle(items)
        a = OptRequest.from_dict(dict(d, seed=seed))
        b = OptRequest.from_dict(dict(items))
        assert a.shape_class() == b.shape_class()
        hash(a.shape_class())                        # stays a valid dict key

    @settings(max_examples=40, deadline=None)
    @given(_fields, st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1),
           st.data())
    def test_seed_shares_bucket_any_other_field_never_does(d, s1, s2, data):
        base = OptRequest.from_dict(dict(d, seed=s1))
        assert base.shape_class() == OptRequest.from_dict(
            dict(d, seed=s2)).shape_class()          # seed never splits
        field = data.draw(st.sampled_from(sorted(_FIELD_VALUES)))
        alt = data.draw(st.sampled_from(
            [v for v in _FIELD_VALUES[field] if v != d[field]]))
        changed = OptRequest.from_dict(dict(d, seed=s1, **{field: alt}))
        assert base.shape_class() != changed.shape_class()
else:
    @pytest.mark.skip(reason="hypothesis not installed; "
                             "pip install -r requirements-dev.txt")
    def test_shape_class_stable_under_field_reordering():
        pass

    @pytest.mark.skip(reason="hypothesis not installed; "
                             "pip install -r requirements-dev.txt")
    def test_seed_shares_bucket_any_other_field_never_does():
        pass


def test_portfolio_normalizes_unused_algo_out_of_the_key():
    """The one documented exception: in portfolio mode ``algo`` is ignored by
    the engine, so it is normalized out of the bucket key."""
    a = OptRequest.from_dict({"fn": "sphere", "n_islands": 4,
                              "portfolio": ["de", "pso"], "algo": "de"})
    b = OptRequest.from_dict({"fn": "sphere", "n_islands": 4,
                              "portfolio": ["de", "pso"], "algo": "ga"})
    assert a.shape_class() == b.shape_class()
    c = OptRequest.from_dict({"fn": "sphere", "n_islands": 4,
                              "portfolio": ["de", "sa"]})
    assert a.shape_class() != c.shape_class()
