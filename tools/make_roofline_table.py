"""Render the EXPERIMENTS.md roofline tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import sys

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.models.config import ModelConfig

# MODEL_FLOPS = 6*N*D tokens (dense) / 6*N_active*D (MoE), per device
ACTIVE_FRACTION_NOTE = True


def active_params(cfg: ModelConfig) -> float:
    """Active parameter count (MoE: top-k + shared experts only)."""
    D, hd = cfg.d_model, cfg.hd
    attn = 2 * D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd
    if cfg.num_experts:
        ff = 3 * D * cfg.expert_ff * cfg.top_k
        if cfg.shared_expert_d_ff:
            ff += 3 * D * cfg.shared_expert_d_ff
    else:
        ff = 3 * D * cfg.d_ff
    if cfg.block_pattern == "attn":
        per_layer = attn + ff
        total = cfg.n_layers * per_layer
    else:
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        ssm = 2 * D * di + 2 * D * n + D * h + di * D
        total = cfg.n_layers * ssm
        if cfg.block_pattern == "ssm+shared_attn":
            total += (cfg.n_layers // cfg.shared_attn_every) * (attn + ff)
    total += cfg.padded_vocab * D * (1 if cfg.tie_embeddings else 2)
    return total


def model_flops(cfg: ModelConfig, shape: str, n_chips: int) -> float:
    sp = SHAPES[shape]
    n = active_params(cfg)
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * n * tokens / n_chips
    if sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * n * tokens / n_chips
    return 2.0 * n * sp.global_batch / n_chips  # decode: 1 token/seq


def row(r: dict) -> str:
    cfg = get_config(r["arch"])
    p = r["per_device"]
    mf = model_flops(cfg, r["shape"], r["n_chips"])
    useful = mf / p["flops"] if p["flops"] else 0.0
    dom = max(p["t_compute"], p["t_memory"], p["t_collective"])
    frac = p["t_compute"] / dom if dom > 0 else 0.0
    amem = r["memory"]["analytic_tpu_bytes"]["total"] / 2 ** 30
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {p['t_compute']*1e3:.2f} | {p['t_memory']*1e3:.2f} "
            f"| {p['t_collective']*1e3:.2f} | {p['bottleneck']} "
            f"| {useful:.2f} | {frac:.2f} | {amem:.2f} |")


def main(out=sys.stdout) -> None:
    header = ("| arch | shape | mesh | tc (ms) | tm (ms) | tx (ms) "
              "| bottleneck | MODEL/HLO flops | roofline frac "
              "| analytic GiB/chip |\n"
              "|---|---|---|---|---|---|---|---|---|---|")
    rows, skips, fails = [], [], []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(path))
        if r.get("status") == "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], row(r)))
        elif r.get("status") == "skip":
            skips.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| SKIP: {r['reason'][:60]} |")
        else:
            fails.append(f"{r['arch']}/{r['shape']}/{r['mesh']}: "
                         f"{r.get('error', '?')[:100]}")
    arch_order = {a: i for i, a in enumerate(ARCHS)}
    shape_order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda t: (arch_order.get(t[0], 99),
                             shape_order.get(t[1], 9), t[2]))
    print(header, file=out)
    for _, _, _, line in rows:
        print(line, file=out)
    print(f"\nskipped cells ({len(skips)}):", file=out)
    for s in skips:
        print(s, file=out)
    if fails:
        print(f"\nFAILED cells ({len(fails)}):", file=out)
        for f_ in fails:
            print(f_, file=out)


if __name__ == "__main__":
    main()
