"""Markdown link check (stdlib-only, offline): every relative link/image in
the given files must resolve to an existing file or directory.

    python tools/check_links.py README.md DESIGN.md CHANGES.md docs

Arguments may be files or directories; a directory is scanned recursively
for ``*.md``. Checks ``[text](target)`` and ``![alt](target)``. External
(``http(s)://``, ``mailto:``) and pure-anchor (``#...``) targets are skipped
— CI stays hermetic. Exits non-zero listing every broken target.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]   # strip section anchors
        if not rel:
            continue
        if not (path.parent / rel).exists():
            line = text.count("\n", 0, m.start()) + 1
            errors.append(f"{path}:{line}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md|DIR [...]", file=sys.stderr)
        return 2
    errors: list[str] = []
    files: list[Path] = []
    for name in argv:
        p = Path(name)
        if p.is_dir():
            found = sorted(p.rglob("*.md"))
            if not found:
                errors.append(f"{name}: directory holds no .md files")
            files.extend(found)
        elif p.exists():
            files.append(p)
        else:
            errors.append(f"{name}: file not found")
    for p in files:
        errors.extend(check_file(p))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_links] {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
