"""Generate docs/API.md — the public-API reference for ``core/``, ``optim/``
and ``kernels/{registry,autotune}`` — from the modules themselves (stdlib-only, offline).

    PYTHONPATH=src python tools/gen_api_docs.py            # (re)write docs/API.md
    PYTHONPATH=src python tools/gen_api_docs.py --check    # CI: fail if stale
                                                           # or docstrings missing

The reference lists every public symbol (classes with their public methods,
functions, dataclasses with init signatures) defined in the covered modules,
in source order, with its signature and first docstring paragraph. ``--check``
enforces two invariants: the committed docs/API.md matches a fresh render
(docs cannot drift from code), and every listed symbol has a docstring (the
public surface stays documented).
"""
from __future__ import annotations

import argparse
import dataclasses
import inspect
import sys
from pathlib import Path

MODULES = (
    "repro.core.api",
    "repro.core.islands",
    "repro.core.executor",
    "repro.core.scheduler",
    "repro.core.pipeline",
    "repro.core.mesh",
    "repro.core.migration",
    "repro.core.portfolio",
    "repro.core.coupling",
    "repro.core.de",
    "repro.core.ga",
    "repro.core.pso",
    "repro.core.sa",
    "repro.core.fa",
    "repro.core.ea",
    "repro.core.bh",
    "repro.core.mc",
    "repro.checkpoint.store",
    "repro.launch.opt_serve",
    "repro.launch.federate",
    "repro.optim.descent",
    "repro.optim.numgrad",
    "repro.optim.adam",
    "repro.kernels.registry",
    "repro.kernels.autotune",
)

OUT = Path(__file__).resolve().parents[1] / "docs" / "API.md"

HEADER = """\
# API reference

Public surface of `core/`, `optim/` and `kernels/{registry,autotune}`, generated from
the source by [`tools/gen_api_docs.py`](../tools/gen_api_docs.py) — do not
edit by hand. Regenerate with:

```bash
PYTHONPATH=src python tools/gen_api_docs.py
```

CI runs `gen_api_docs.py --check`, which fails when this file is stale or a
listed symbol is missing a docstring. Architecture context: [DESIGN.md](../DESIGN.md).
"""


def _first_paragraph(doc: str | None) -> str:
    """First docstring paragraph, collapsed to one line ('' when absent)."""
    if not doc:
        return ""
    lines = []
    for line in inspect.cleandoc(doc).splitlines():
        if not line.strip():
            break
        lines.append(line.strip())
    return " ".join(lines)


def _signature(obj) -> str:
    try:
        sig = str(inspect.signature(obj))
    except (ValueError, TypeError):
        return "(...)"
    if inspect.isclass(obj) and sig.endswith(" -> None"):
        sig = sig[: -len(" -> None")]       # dataclass __init__ noise
    return sig


def _public_members(mod) -> list[tuple[str, object]]:
    """(name, obj) for classes/functions defined in ``mod``, source order."""
    out = []
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue
        try:
            line = inspect.getsourcelines(obj)[1]
        except (OSError, TypeError):
            line = 0
        out.append((line, name, obj))
    return [(n, o) for _, n, o in sorted(out)]


def _class_methods(cls) -> list[tuple[str, object]]:
    """Public methods defined directly on ``cls`` (not inherited), source order."""
    out = []
    for name, obj in vars(cls).items():
        if name.startswith("_") or not inspect.isfunction(obj):
            continue
        out.append((inspect.getsourcelines(obj)[1], name, obj))
    return [(n, o) for _, n, o in sorted(out)]


def render(missing: list[str]) -> str:
    parts = [HEADER]
    for modname in MODULES:
        __import__(modname)
        mod = sys.modules[modname]
        parts.append(f"\n## `{modname}`\n")
        moddoc = _first_paragraph(mod.__doc__)
        if moddoc:
            parts.append(f"{moddoc}\n")
        else:
            missing.append(modname)
        for name, obj in _public_members(mod):
            qual = f"{modname}.{name}"
            doc = _first_paragraph(obj.__doc__)
            if inspect.isclass(obj):
                kind = ("dataclass" if dataclasses.is_dataclass(obj)
                        else "class")
                parts.append(f"### {kind} `{name}{_signature(obj)}`\n")
                # dataclasses inherit __doc__ from the auto-generated repr
                # only when undocumented; treat the synthesized one as absent
                if doc.startswith(f"{name}(") and obj.__doc__ == doc:
                    doc = ""
                if doc:
                    parts.append(f"{doc}\n")
                else:
                    missing.append(qual)
                for mname, mobj in _class_methods(obj):
                    mdoc = _first_paragraph(mobj.__doc__)
                    parts.append(f"- `{mname}{_signature(mobj)}` — {mdoc}\n")
                    if not mdoc:
                        missing.append(f"{qual}.{mname}")
            else:
                parts.append(f"### `{name}{_signature(obj)}`\n")
                if doc:
                    parts.append(f"{doc}\n")
                else:
                    missing.append(qual)
    return "\n".join(parts)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="verify docs/API.md is current and fully documented")
    ap.add_argument("--out", type=Path, default=OUT)
    args = ap.parse_args()

    missing: list[str] = []
    text = render(missing)
    if missing:
        for sym in missing:
            print(f"missing docstring: {sym}", file=sys.stderr)
        return 1
    if args.check:
        if not args.out.exists() or args.out.read_text() != text:
            print(f"{args.out} is stale — rerun tools/gen_api_docs.py",
                  file=sys.stderr)
            return 1
        print(f"[gen_api_docs] {args.out} is current")
        return 0
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(text)
    print(f"[gen_api_docs] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
