"""List the largest materialized buffers in an HLO dump (debug helper)."""
import re
import sys

BP = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "u32": 4, "f16": 2, "s64": 8}


def main(path: str, min_mb: float = 256.0, top: int = 24) -> None:
    sizes = []
    for line in open(path):
        m = re.match(r"\s*(?:ROOT )?%[\w\.\-]+ = ((?:\([^)]*\)|\S+)) ([\w\-\.]+)\(", line)
        if not m:
            continue
        shape, op = m.group(1), m.group(2)
        if op == "parameter":
            continue
        tot = 0
        for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", shape):
            if dt not in BP:
                continue
            n = 1
            for d in (dims.split(",") if dims else []):
                n *= int(d)
            tot += n * BP[dt]
        if tot >= min_mb * 2 ** 20:
            sizes.append((tot, op, shape[:90]))
    sizes.sort(reverse=True)
    seen = set()
    for t, op, shape in sizes:
        if (op, shape) in seen:
            continue
        seen.add((op, shape))
        print(f"{t/2**30:8.2f} GiB {op:24s} {shape}")
        if len(seen) >= top:
            break


if __name__ == "__main__":
    main(sys.argv[1], *(float(a) for a in sys.argv[2:3]))
